//! Figure 08 (extension) — Prefill/decode disaggregation × KV prefix
//! caching: the RAGO-style "where each placement wins" sweep. Placement
//! (collocated vs disaggregated generator pools) × offered load ×
//! context repeat rate, reporting p99 TTFT, goodput, and the KV-transfer
//! tax each handoff pays.
//!
//! The claim this bench pins down: splitting the generator into prefill
//! and decode pools wins exactly when the things the split enables —
//! independently sized pools and a KV prefix cache that collapses
//! repeat-heavy prefill to `KV_PREFIX_HIT_COST_FRAC` of its cost —
//! outweigh the per-request KV handoff (`profile::models::
//! KvTransferModel`). On a Zipf repeat-heavy trace the disaggregated
//! arm's effective prefill capacity grows with skew and p99 TTFT drops
//! below collocated; inflate the transfer cost (slow interconnect) and
//! the ordering flips back — the same two regimes the allocation LP
//! prices when `FlowProblem::with_placement` chooses pool splits.
//!
//! Both arms run the same DES and trace; the disaggregated arm re-solves
//! its LP with the placement-aware columns and provisions prefill/decode
//! pools from the solution. Accepts `--smoke` for the CI quick pass.

use harmonia::profile::models::{zipf_hit_rate, KvTransferModel};
use harmonia::profile::{GenBatching, GenPlacement};
use harmonia::sim::{SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::util::bench::{smoke, smoke_scale};
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

/// Collocated continuous-batching generator capacity on the paper
/// testbed with the workload below (k ∈ [50, 100] → prompt ≈ 60 tokens,
/// ~0.016 s prefill + ~0.11 s decode per visit across 32 GPU instances
/// × 4 slots ≈ 1000 req/s). The retriever pool stays out of the way, so
/// generator placement is the binding constraint through the sweep.
const CAPACITY: f64 = 1000.0;
const SLO: f64 = 2.0;
const SEED: u64 = 0xF16_08;

fn run(
    placement: GenPlacement,
    kv: KvTransferModel,
    hit: f64,
    rate: f64,
    n: usize,
) -> harmonia::sim::SimResult {
    let trace = TraceConfig {
        rate,
        n,
        slo: Some(SLO),
        k_lo: 50,
        k_hi: 100,
        ..TraceConfig::default()
    };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.gen_batching = GenBatching::Continuous;
    cfg.gen_placement = placement;
    cfg.kv_transfer = kv;
    cfg.kv_prefix_hit_rate = hit;
    SimWorld::simulate(apps::vanilla_rag(), cfg)
}

fn main() {
    let n = smoke_scale(2500, 300);
    // Zipf(1.3) contexts, 90% cacheable mass, 4096-entry cache over a
    // 2048-chain working set — the repeat-heavy end of the sweep.
    let zipf = zipf_hit_rate(1.3, 0.9, 4096, 2048);
    println!(
        "Figure 08: generator placement x load x repeat rate on v-rag \
         (collocated capacity = {CAPACITY} req/s, SLO = {SLO} s, n = {n}{})\n",
        if smoke() { ", --smoke" } else { "" }
    );

    let repeats = [("none", 0.0), ("mixed", 0.5), ("zipf", zipf)];
    let multipliers = [0.7, 1.0, 1.4];
    // [multiplier] → collocated p99 TTFT; [multiplier][repeat] → disagg.
    let mut col_ttft = [0.0f64; 3];
    let mut dis_ttft = [[0.0f64; 3]; 3];

    for (mi, mult) in multipliers.iter().enumerate() {
        let rate = CAPACITY * mult;
        let mut t = Table::new(
            &format!("offered load {}x collocated capacity ({} req/s)", f(*mult, 1), f(rate, 0)),
            &["placement", "repeat", "goodput/s", "p99 TTFT (s)", "p99 e2e (s)", "hit %", "xfer ms"],
        );
        let col = run(GenPlacement::Collocated, KvTransferModel::default(), 0.0, rate, n);
        let cg = col.report.gen.expect("continuous mode records gen stats");
        col_ttft[mi] = cg.ttft_p99;
        t.row(&[
            "collocated".into(),
            "-".into(),
            f(col.report.goodput(), 1),
            f(cg.ttft_p99, 3),
            f(col.report.p99, 3),
            "-".into(),
            "-".into(),
        ]);
        for (ri, (rname, hit)) in repeats.iter().enumerate() {
            let dis = run(GenPlacement::Disaggregated, KvTransferModel::default(), *hit, rate, n);
            let dg = dis.report.gen.expect("continuous mode records gen stats");
            let dd = dis.report.disagg.expect("disaggregated runs record a disagg section");
            dis_ttft[mi][ri] = dg.ttft_p99;
            t.row(&[
                "disaggregated".into(),
                rname.to_string(),
                f(dis.report.goodput(), 1),
                f(dg.ttft_p99, 3),
                f(dis.report.p99, 3),
                f(dd.kv_prefix.hit_rate() * 100.0, 1),
                f(dd.mean_transfer() * 1e3, 2),
            ]);
        }
        t.print();
        println!();
    }

    // The flip side: a slow interconnect (200x the per-handoff transfer
    // cost) at moderate load, no repeats — the LP's collocated regime.
    let slow = KvTransferModel { scale: 200.0, ..KvTransferModel::default() };
    let slow_rate = CAPACITY * 0.4;
    let col_slow = run(GenPlacement::Collocated, slow, 0.0, slow_rate, n);
    let dis_slow = run(GenPlacement::Disaggregated, slow, 0.0, slow_rate, n);
    let csg = col_slow.report.gen.expect("gen stats");
    let dsg = dis_slow.report.gen.expect("gen stats");
    let dsd = dis_slow.report.disagg.expect("disagg section");
    let mut t = Table::new(
        &format!("slow interconnect (200x transfer), {} req/s, no repeats", f(slow_rate, 0)),
        &["placement", "goodput/s", "p99 TTFT (s)", "mean e2e (s)", "xfer ms"],
    );
    t.row(&[
        "collocated".into(),
        f(col_slow.report.goodput(), 1),
        f(csg.ttft_p99, 3),
        f(col_slow.report.mean_latency, 3),
        "-".into(),
    ]);
    t.row(&[
        "disaggregated".into(),
        f(dis_slow.report.goodput(), 1),
        f(dsg.ttft_p99, 3),
        f(dis_slow.report.mean_latency, 3),
        f(dsd.mean_transfer() * 1e3, 2),
    ]);
    t.print();
    println!();

    // Shape checks — the acceptance criteria, same regimes the fixed-seed
    // DES regressions pin (`sim::simrun` disaggregation tests).
    let disagg_wins = dis_ttft[2][2] < col_ttft[2];
    let repeat_helps = dis_ttft[2][2] < dis_ttft[2][0];
    let col_wins_slow = csg.ttft_p99 < dsg.ttft_p99;
    println!(
        "SHAPE CHECK: disagg + prefix cache cuts p99 TTFT vs collocated at 1.4x load, zipf repeats: {}",
        if disagg_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: repeat rate strictly improves disaggregated p99 TTFT at 1.4x load: {}",
        if repeat_helps { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: collocated wins p99 TTFT when KV transfer dominates (200x interconnect): {}",
        if col_wins_slow { "REPRODUCED" } else { "NOT reproduced" }
    );
}
