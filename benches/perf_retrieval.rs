//! Retrieval data-plane performance — the second *measured* number in
//! the repo (the retrieval counterpart of `perf_des.rs`).
//!
//! Exercises the three mechanisms of the quantized/blocked scoring hot
//! path on one corpus:
//!
//!   - **f32 scan** — blocked 8-lane `dot_f32` kernels over the padded
//!     row layout, streamed through the bounded-heap top-k;
//!   - **SQ8 scan** — u8 codes at 1/4 the scan bandwidth, asymmetric
//!     u8·f32 scoring, exact f32 rescoring over `rerank_factor × k`
//!     survivors (recall@10 must stay within 0.02 of f32 — asserted);
//!   - **kernel microbenches** — the raw `dot_f32` block scan, bounded-
//!     heap selection, and the exact full-corpus scan in isolation.
//!
//! Emits `BENCH_retrieval.json` (scored-vectors/sec, per-query p50/p99,
//! recall@10 vs exact for both modes, per-kernel breakdown) via
//! `util::bench::emit_json`, and gates against `benches/baselines/`
//! when a checked-in baseline exists: >20% scored-vectors/sec
//! regression fails the run (CI runs `--smoke`; see
//! `make bench-retrieval`).
//!
//! Accepts `--smoke` (see `util::bench::smoke`): a 20k-row corpus
//! instead of 200k, same code paths, same artifact shape. The measured
//! f32-vs-SQ8 per-query p50 ratio is the calibration source for
//! `profile::models::QUANTIZED_SERVICE_FRAC` (re-fit it from
//! `sq8_p50_ratio` once this has run on real hardware).

use std::time::Instant;

use harmonia::retrieval::{dot_f32, IvfIndex, IvfParams, Quantization, TopK};
use harmonia::util::bench::{
    bench, black_box, emit_json, json_number_field, smoke, smoke_scale, stats_from, Json,
};
use harmonia::util::table::{f, Table};
use harmonia::workload::{Corpus, QueryGen};

const SEED: u64 = 0x4E7_12E7;
const DIM: usize = 64;
const K: usize = 10;
/// Regression gate: fail when scored-vectors/sec drops below this
/// fraction of the checked-in baseline.
const GATE_FRAC: f64 = 0.8;

/// Sorted-sample percentile (nearest-rank on the sorted slice).
fn pct(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() as f64 - 1.0) * p) as usize]
}

struct ScanRun {
    mode: &'static str,
    scored_per_sec: f64,
    p50_s: f64,
    p99_s: f64,
    recall_at_k: f64,
    scan_bytes_per_vector: usize,
}

/// Time per-query searches over the whole query set until the clock
/// budget is spent; `scanned_per_pass` is the true candidate count the
/// probe covers (computed outside the timed region).
fn scan_run(
    mode: &'static str,
    idx: &IvfIndex,
    queries: &[Vec<f32>],
    ef: usize,
    exact: &[Vec<harmonia::retrieval::SearchResult>],
    min_secs: f64,
) -> ScanRun {
    let scanned_per_pass: usize = queries.iter().map(|q| idx.candidates(q, ef).len()).sum();
    let mut searcher = idx.searcher();
    // Warmup pass (page in rows/codes, size the scratch).
    for q in queries {
        black_box(searcher.search(q, K, ef));
    }
    let mut samples: Vec<f64> = Vec::new();
    let mut passes = 0usize;
    let start = Instant::now();
    while passes == 0 || start.elapsed().as_secs_f64() < min_secs {
        for q in queries {
            let t0 = Instant::now();
            black_box(searcher.search(q, K, ef));
            samples.push(t0.elapsed().as_secs_f64());
        }
        passes += 1;
    }
    let elapsed: f64 = samples.iter().sum();
    samples.sort_by(f64::total_cmp);
    let mut recall = 0.0;
    for (q, ex) in queries.iter().zip(exact) {
        recall += IvfIndex::recall(&idx.search(q, K, ef), ex);
    }
    ScanRun {
        mode,
        scored_per_sec: (scanned_per_pass * passes) as f64 / elapsed.max(1e-12),
        p50_s: pct(&samples, 0.50),
        p99_s: pct(&samples, 0.99),
        recall_at_k: recall / queries.len() as f64,
        scan_bytes_per_vector: idx.scan_bytes_per_vector(),
    }
}

fn out_path() -> std::path::PathBuf {
    let dir = std::env::var("BENCH_OUT_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    std::path::Path::new(&dir).join("BENCH_retrieval.json")
}

fn baseline_path(smoke: bool) -> std::path::PathBuf {
    let file = if smoke { "BENCH_retrieval.smoke.json" } else { "BENCH_retrieval.json" };
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/baselines").join(file)
}

fn main() {
    let smoke = smoke();
    let n = smoke_scale(200_000, 20_000);
    let nq = smoke_scale(256, 64);
    // Probe ~2% of the corpus per query — the operating regime where the
    // scan kernel (not centroid scoring) dominates.
    let ef = (n / 50).max(512);
    let min_secs = if smoke { 0.5 } else { 3.0 };
    println!(
        "retrieval data-plane perf: n={n} dim={DIM} k={K} search_ef={ef}{}\n",
        if smoke { " (--smoke)" } else { "" }
    );

    let corpus = Corpus::generate(n, 64, 64, SEED);
    let mut vectors = Vec::with_capacity(n * DIM);
    for p in &corpus.passages {
        vectors.extend(Corpus::hash_embed(&p.text, DIM));
    }
    let params = IvfParams {
        n_lists: (n / 256).max(16),
        kmeans_iters: 4,
        seed: SEED,
        ..IvfParams::default()
    };

    let t0 = Instant::now();
    let f32_idx = IvfIndex::build(vectors.clone(), DIM, params);
    let build_f32_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let sq8_idx = IvfIndex::build(
        vectors.clone(),
        DIM,
        IvfParams { quantization: Quantization::SQ8, ..params },
    );
    let build_sq8_s = t0.elapsed().as_secs_f64();
    println!(
        "built f32 index in {}, sq8 index in {} ({} lists)",
        f(build_f32_s, 2),
        f(build_sq8_s, 2),
        f32_idx.n_lists()
    );

    let mut qg = QueryGen::new(&corpus, 7);
    let queries: Vec<Vec<f32>> =
        (0..nq).map(|_| Corpus::hash_embed(&qg.next().text, DIM)).collect();
    // Ground truth is the exact f32 scan (identical rows in both modes).
    let exact: Vec<_> = queries.iter().map(|q| f32_idx.search_exact(q, K)).collect();

    let runs = [
        scan_run("f32", &f32_idx, &queries, ef, &exact, min_secs),
        scan_run("sq8", &sq8_idx, &queries, ef, &exact, min_secs),
    ];
    let mut t = Table::new(
        "probe scan (per-query)",
        &["mode", "scored-vec/s", "p50 (us)", "p99 (us)", "recall@10", "scan B/vec"],
    );
    for r in &runs {
        t.row(&[
            r.mode.to_string(),
            f(r.scored_per_sec, 0),
            f(r.p50_s * 1e6, 1),
            f(r.p99_s * 1e6, 1),
            f(r.recall_at_k, 4),
            r.scan_bytes_per_vector.to_string(),
        ]);
    }
    t.print();

    let (f32_run, sq8_run) = (&runs[0], &runs[1]);
    let sq8_p50_ratio = sq8_run.p50_s / f32_run.p50_s.max(1e-12);
    println!(
        "\nsq8/f32 p50 ratio: {} (calibration source for QUANTIZED_SERVICE_FRAC)",
        f(sq8_p50_ratio, 3)
    );
    // The pinned recall band — the same invariant the property suite
    // enforces, here on the bench corpus.
    assert!(
        sq8_run.recall_at_k >= f32_run.recall_at_k - 0.02,
        "SQ8 recall@{K} {} fell more than 0.02 below f32 {}",
        sq8_run.recall_at_k,
        f32_run.recall_at_k
    );

    // Kernel microbenches: the raw pieces the scans are made of.
    println!("\nkernel breakdown:");
    let rows = 4096.min(n);
    let q0 = &queries[0];
    let dot_block = bench("dot_f32 x4096 rows", 3, 20, min_secs / 4.0, || {
        let mut acc = 0f32;
        for i in 0..rows {
            acc += dot_f32(f32_idx.vector(i), q0);
        }
        black_box(acc);
    });
    println!("  {}", dot_block.summary());
    let scores: Vec<f32> = (0..rows).map(|i| dot_f32(f32_idx.vector(i), q0)).collect();
    let topk_sel = bench("topk(10) x4096 scores", 3, 20, min_secs / 4.0, || {
        let mut top = TopK::new(K);
        for (i, &s) in scores.iter().enumerate() {
            top.push(i, s);
        }
        black_box(top.into_sorted());
    });
    println!("  {}", topk_sel.summary());
    let mut exact_samples: Vec<f64> = Vec::new();
    for q in queries.iter().take(16) {
        let t0 = Instant::now();
        black_box(f32_idx.search_exact(q, K));
        exact_samples.push(t0.elapsed().as_secs_f64());
    }
    let exact_scan = stats_from("search_exact (full corpus)", &mut exact_samples);
    println!("  {}", exact_scan.summary());

    let kernel_json = |s: &harmonia::util::bench::BenchStats| {
        Json::obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("mean_s", Json::Num(s.mean)),
            ("p50_s", Json::Num(s.p50)),
        ])
    };
    let run_json = |r: &ScanRun| {
        Json::obj(vec![
            ("mode", Json::Str(r.mode.into())),
            ("vectors_per_sec", Json::Num(r.scored_per_sec)),
            ("p50_s", Json::Num(r.p50_s)),
            ("p99_s", Json::Num(r.p99_s)),
            ("recall_at_10", Json::Num(r.recall_at_k)),
            ("scan_bytes_per_vector", Json::Int(r.scan_bytes_per_vector as i64)),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_retrieval".into())),
        ("smoke", Json::Bool(smoke)),
        ("corpus_n", Json::Int(n as i64)),
        ("dim", Json::Int(DIM as i64)),
        ("k", Json::Int(K as i64)),
        ("search_ef", Json::Int(ef as i64)),
        ("n_lists", Json::Int(f32_idx.n_lists() as i64)),
        // Headline + gate key: the f32 scan's scored-vectors/sec.
        ("scored_vectors_per_sec", Json::Num(f32_run.scored_per_sec)),
        ("sq8_p50_ratio", Json::Num(sq8_p50_ratio)),
        ("recall_delta_sq8_vs_f32", Json::Num(sq8_run.recall_at_k - f32_run.recall_at_k)),
        ("build_f32_secs", Json::Num(build_f32_s)),
        ("build_sq8_secs", Json::Num(build_sq8_s)),
        ("scans", Json::Arr(runs.iter().map(run_json).collect())),
        (
            "kernels",
            Json::Arr(vec![
                kernel_json(&dot_block),
                kernel_json(&topk_sel),
                kernel_json(&exact_scan),
            ]),
        ),
    ]);
    let path = out_path();
    emit_json(&path, &doc).expect("write BENCH_retrieval.json");
    // Self-check: the artifact must be machine-readable by the same
    // parser the regression gate uses.
    let text = std::fs::read_to_string(&path).expect("re-read artifact");
    for key in ["scored_vectors_per_sec", "sq8_p50_ratio", "recall_delta_sq8_vs_f32"] {
        assert!(
            json_number_field(&text, key).is_some(),
            "emitted BENCH_retrieval.json is missing a readable {key}"
        );
    }
    println!("\nwrote {}", path.display());

    // Regression gate: only once a baseline is checked in.
    let base = baseline_path(smoke);
    match std::fs::read_to_string(&base) {
        Ok(btext) => match json_number_field(&btext, "scored_vectors_per_sec") {
            Some(bline) if bline > 0.0 => {
                let ratio = f32_run.scored_per_sec / bline;
                println!(
                    "baseline {}: {} scored-vec/s -> ratio {}",
                    base.display(),
                    f(bline, 0),
                    f(ratio, 3)
                );
                if ratio < GATE_FRAC {
                    eprintln!(
                        "REGRESSION: scored-vectors/sec fell to {}x of baseline (gate {GATE_FRAC}x)",
                        f(ratio, 3)
                    );
                    std::process::exit(1);
                }
            }
            _ => println!("baseline {} unreadable; gate skipped", base.display()),
        },
        Err(_) => println!(
            "no checked-in baseline at {} yet; gate skipped (record one in a cargo-equipped env)",
            base.display()
        ),
    }
}
