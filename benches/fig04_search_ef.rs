//! Figure 4 — Shifting Optimal Resource Allocation: retrieval latency and
//! recall as a function of the `search_ef` parameter, for several K.
//!
//! Paper's claim (ChromaDB): for small K, low `search_ef` values can be
//! up to ~20× faster (at reduced recall).

use std::time::Instant;

use harmonia::retrieval::{IvfIndex, IvfParams};
use harmonia::util::table::{f, Table};
use harmonia::workload::{Corpus, QueryGen};

fn main() {
    let n = 40_000;
    let dim = 64;
    println!("Figure 4 reproduction: IVF search latency/recall vs search_ef (corpus n={n}, d={dim})\n");

    let corpus = Corpus::generate(n, 64, 64, 0xF16_4);
    let mut vectors = Vec::with_capacity(n * dim);
    for p in &corpus.passages {
        vectors.extend(Corpus::hash_embed(&p.text, dim));
    }
    let index = IvfIndex::build(
        vectors,
        dim,
        IvfParams { n_lists: 256, kmeans_iters: 6, seed: 1, ..IvfParams::default() },
    );

    let mut qg = QueryGen::new(&corpus, 7);
    let queries: Vec<Vec<f32>> =
        (0..48).map(|_| Corpus::hash_embed(&qg.next().text, dim)).collect();

    let efs = [100usize, 400, 1600, 6400, 25600, n];

    for k in [1usize, 10, 100] {
        let exact: Vec<_> = queries.iter().map(|q| index.search_exact(q, k)).collect();
        // (ef, latency, recall)
        let mut rows = Vec::new();
        for &ef in &efs {
            let t0 = Instant::now();
            let mut results = Vec::with_capacity(queries.len());
            for q in &queries {
                results.push(index.search(q, k, ef));
            }
            let lat = t0.elapsed().as_secs_f64() / queries.len() as f64;
            let recall: f64 = results
                .iter()
                .zip(&exact)
                .map(|(g, e)| IvfIndex::recall(g, e))
                .sum::<f64>()
                / queries.len() as f64;
            rows.push((ef, lat, recall));
        }
        let full = rows.last().unwrap().1;
        let mut t = Table::new(
            &format!("K = {k}"),
            &["search_ef", "latency (us/query)", "recall@k", "speedup vs full scan"],
        );
        for &(ef, lat, recall) in &rows {
            t.row(&[
                ef.to_string(),
                f(lat * 1e6, 1),
                f(recall, 3),
                format!("{}x", f(full / lat, 1)),
            ]);
        }
        t.print();
        let max_speedup = full / rows[0].1;
        println!("  max speedup at K={k}: {}x (paper: up to ~20x for small K)\n", f(max_speedup, 1));
        if k == 1 {
            println!(
                "SHAPE CHECK (small K): low ef ≥8x faster than full scan: {}\n",
                if max_speedup >= 8.0 { "REPRODUCED" } else { "NOT reproduced" }
            );
        }
    }
}
