//! Figure 11 — SLO violations vs load for the four workflows.
//!
//! SLO threshold = 2× the average request latency under Harmonia at low
//! load (the paper's definition). Claims: V-RAG −11.8% at moderate load
//! (parity at saturation); C-RAG −21%/−18%; S-RAG −41.3% even at high
//! load; A-RAG −78.4% even at high load (execution heterogeneity creates
//! slack the EDF scheduler exploits).

use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::apps;
use harmonia::util::table::{f, Table};

fn main() {
    println!("Figure 11 reproduction: SLO violation % vs offered load\n");
    let n = 4000;
    let seed = 0xF16_11;
    let apps_list = ["v-rag", "c-rag", "s-rag", "a-rag"];
    let paper_best = [11.8, 21.0, 41.3, 78.4];

    let mut best_reduction = vec![0.0f64; apps_list.len()];
    for (ai, app) in apps_list.iter().enumerate() {
        // SLO = 2x low-load mean latency under Harmonia.
        let low = run_point(SystemKind::Harmonia, apps::by_name(app).unwrap(), 2.0, 300, None, seed);
        let slo = 2.0 * low.report.mean_latency;
        let rates: &[f64] = if *app == "v-rag" {
            &[64.0, 192.0, 320.0, 448.0, 576.0, 704.0]
        } else {
            &[48.0, 96.0, 160.0, 224.0, 288.0, 352.0]
        };
        let mut t = Table::new(
            &format!("{app}: SLO violation % (SLO = {} s)", f(slo, 3)),
            &["rate", "harmonia", "langchain", "haystack", "reduction vs best baseline"],
        );
        for &rate in rates {
            let h = run_point(SystemKind::Harmonia, apps::by_name(app).unwrap(), rate, n, Some(slo), seed);
            let l = run_point(SystemKind::LangChain, apps::by_name(app).unwrap(), rate, n, Some(slo), seed);
            let y = run_point(SystemKind::Haystack, apps::by_name(app).unwrap(), rate, n, Some(slo), seed);
            let hv = h.report.slo_violation_rate * 100.0;
            let lv = l.report.slo_violation_rate * 100.0;
            let yv = y.report.slo_violation_rate * 100.0;
            let base = lv.min(yv);
            let reduction = if base > 0.5 { (1.0 - hv / base) * 100.0 } else { 0.0 };
            best_reduction[ai] = best_reduction[ai].max(reduction);
            t.row(&[
                f(rate, 0),
                f(hv, 1),
                f(lv, 1),
                f(yv, 1),
                format!("{}%", f(reduction, 1)),
            ]);
        }
        t.print();
        println!(
            "  best violation reduction: {}% (paper: up to {}%)\n",
            f(best_reduction[ai], 1),
            paper_best[ai]
        );
    }

    let mut t = Table::new("summary (paper Figure 11)", &["workflow", "best reduction %", "paper %"]);
    for (i, app) in apps_list.iter().enumerate() {
        t.row(&[app.to_string(), f(best_reduction[i], 1), f(paper_best[i], 1)]);
    }
    t.print();
    println!(
        "\nSHAPE CHECK: recursive/heterogeneous workflows (s-rag, a-rag) see the biggest reductions: {}",
        if best_reduction[2] > best_reduction[0] && best_reduction[3] > best_reduction[0] {
            "REPRODUCED"
        } else {
            "NOT reproduced"
        }
    );
}
