//! Figure 06 (extension) — Continuous (iteration-level) batching for the
//! generator: throughput/goodput, p99 TTFT, and p99 per-token latency vs
//! offered load, static run-to-completion batches vs continuous batching.
//!
//! The claim this bench pins down: run-to-completion batching makes a
//! short answer co-batched with a long one wait out the longest decode
//! in the batch, and blocks mid-batch admissions entirely — so past
//! moderate load, TTFT and per-token pace collapse long before the GPU
//! itself is out of decode throughput. Iteration-level batching
//! (vLLM/Orca-style: prefill-on-join into a free slot, slot freed the
//! step its request emits EOS) prices each request at
//! `prefill + own_steps × step(occupancy)`, which is the "throughput
//! gains exceeding 48%" axis of the source paper's LLM stage.
//!
//! Both policies run the same DES, the same trace, and re-profile their
//! LP priors under their own `profile::models::DecodeCostModel` mode —
//! the allocator and admission slack see what the generator actually
//! does in each regime.
//!
//! Accepts `--smoke` (see `util::bench::smoke`) for the CI quick pass.

use harmonia::profile::GenBatching;
use harmonia::sim::{SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::util::bench::{smoke, smoke_scale};
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

/// Static-batching generator capacity on the paper testbed with the
/// generator-stressing workload below: 32 GPU instances × 4 decode slots
/// per ~0.24 s run-to-completion batch turnaround ≈ 540 req/s. The
/// retriever pool (k ∈ [50, 100] → ~0.05 s/visit) stays out of the way
/// through the whole sweep, so the batching policy is the binding
/// constraint.
const CAPACITY: f64 = 540.0;
const SLO: f64 = 2.0;
const SEED: u64 = 0xF16_06;

fn run(mode: GenBatching, rate: f64, n: usize) -> harmonia::sim::SimResult {
    let trace = TraceConfig {
        rate,
        n,
        slo: Some(SLO),
        k_lo: 50,
        k_hi: 100,
        ..TraceConfig::default()
    };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.gen_batching = mode;
    SimWorld::simulate(apps::vanilla_rag(), cfg)
}

fn main() {
    let n = smoke_scale(3000, 400);
    println!(
        "Figure 06: continuous vs static generator batching on v-rag \
         (static capacity ≈ {CAPACITY} req/s, SLO = {SLO} s, n = {n}{})\n",
        if smoke() { ", --smoke" } else { "" }
    );

    let policies = [("static", GenBatching::Static), ("continuous", GenBatching::Continuous)];
    let multipliers = [0.5, 1.0, 1.5, 2.0, 2.5];
    // [policy][multiplier] → (p99 ttft, goodput, p99 tok).
    let mut ttft = [[0.0f64; 5]; 2];
    let mut good = [[0.0f64; 5]; 2];
    let mut tok = [[0.0f64; 5]; 2];

    for (mi, mult) in multipliers.iter().enumerate() {
        let rate = CAPACITY * mult;
        let mut t = Table::new(
            &format!("offered load {}x static capacity ({} req/s)", f(*mult, 1), f(rate, 0)),
            &["policy", "goodput/s", "p99 TTFT (s)", "p99 tok (ms)", "p99 e2e (s)", "viol %"],
        );
        for (pi, (name, mode)) in policies.iter().enumerate() {
            let r = run(*mode, rate, n);
            let rep = &r.report;
            let g = rep.gen.expect("stepped modes record gen stats");
            ttft[pi][mi] = g.ttft_p99;
            good[pi][mi] = rep.goodput();
            tok[pi][mi] = g.tok_p99;
            t.row(&[
                name.to_string(),
                f(rep.goodput(), 1),
                f(g.ttft_p99, 3),
                f(g.tok_p99 * 1e3, 2),
                f(rep.p99, 3),
                f(rep.slo_violation_rate * 100.0, 1),
            ]);
        }
        t.print();
        println!();
    }

    // Shape checks — the acceptance criterion: at ≥2× load continuous
    // batching strictly improves p99 TTFT and goodput over static.
    let hi: Vec<usize> = multipliers
        .iter()
        .enumerate()
        .filter(|(_, m)| **m >= 2.0)
        .map(|(i, _)| i)
        .collect();
    let ttft_wins = hi.iter().all(|&i| ttft[1][i] < ttft[0][i]);
    let goodput_wins = hi.iter().all(|&i| good[1][i] > good[0][i]);
    let tok_wins = hi.iter().all(|&i| tok[1][i] < tok[0][i]);
    println!(
        "SHAPE CHECK: continuous strictly cuts p99 TTFT vs static at >=2x load: {}",
        if ttft_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: continuous strictly raises goodput vs static at >=2x load: {}",
        if goodput_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: continuous strictly cuts p99 per-token latency at >=2x load: {}",
        if tok_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
}
