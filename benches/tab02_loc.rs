//! Table 2 — Lines of code to implement each RAG application on top of
//! Harmonia's abstractions.
//!
//! Paper: abstraction implementation 32/78/64/89 LoC and workflow
//! specification 6/12/14/20 LoC for V/C/S/A-RAG. We count the same split
//! in `spec::apps`: the per-app workflow-spec function body is the
//! "workflow specification"; the shared serving-ready machinery the app
//! relies on (builder + graph plumbing it exercises) plays the role of
//! the abstraction code a user would otherwise write.

use harmonia::util::table::Table;

const APPS_SRC: &str = include_str!("../rust/src/spec/apps.rs");

/// Count non-empty, non-comment lines of `fn name(...) { ... }`.
fn fn_loc(src: &str, name: &str) -> usize {
    let needle = format!("pub fn {name}(");
    let start = src.find(&needle).unwrap_or_else(|| panic!("fn {name} not found"));
    let body = &src[start..];
    let mut depth = 0usize;
    let mut started = false;
    let mut loc = 0;
    for line in body.lines() {
        let code = line.trim();
        if !started {
            if code.contains('{') {
                started = true;
                depth += code.matches('{').count();
                depth -= code.matches('}').count();
            }
            continue;
        }
        depth += code.matches('{').count();
        if code.matches('}').count() > depth {
            break;
        }
        depth -= code.matches('}').count();
        if !code.is_empty() && !code.starts_with("//") {
            loc += 1;
        }
        if depth == 0 {
            break;
        }
    }
    loc
}

fn main() {
    println!("Table 2 reproduction: LoC to implement each RAG on Harmonia\n");
    let apps = [
        ("v-rag", "vanilla_rag", 32, 6),
        ("c-rag", "corrective_rag", 78, 12),
        ("s-rag", "self_rag", 64, 14),
        ("a-rag", "adaptive_rag", 89, 20),
    ];
    let mut t = Table::new(
        "workflow specification LoC",
        &["app", "spec LoC (ours)", "paper spec LoC", "paper abstraction LoC"],
    );
    let mut all_small = true;
    for (app, func, paper_abs, paper_spec) in apps {
        let loc = fn_loc(APPS_SRC, func);
        if loc > 60 {
            all_small = false;
        }
        t.row(&[
            app.to_string(),
            loc.to_string(),
            paper_spec.to_string(),
            paper_abs.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nSHAPE CHECK: each workflow is specified in tens of lines on top of the\n\
         serving-ready abstractions (paper: 6–20 spec / 32–89 abstraction): {}",
        if all_small { "REPRODUCED" } else { "NOT reproduced" }
    );
}
