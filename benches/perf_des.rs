//! DES core performance — the first *measured* number in the repo.
//!
//! Drives ≥10M simulated requests through the calendar-queue event loop
//! across three representative apps (ROADMAP item 4):
//!
//!   - `v-rag-cached` — the high-rate single-path workload: cache-
//!     adjusted retrieval keeps service short, so the event loop itself
//!     dominates (5M requests at 600 req/s).
//!   - `hybrid-rag`   — fork/join dataflow: every request exercises the
//!     branch arena (fork, join cells, loser cancellation) that replaced
//!     the `(req, branch)`-keyed HashMap swarm (2M at 64 req/s).
//!   - `disagg-zipf`  — prefill/decode disaggregation with a Zipf KV
//!     prefix cache: continuous batching, KV handoff events, and the
//!     decode pool's dense per-node queues (3M at 600 req/s).
//!
//! Emits `BENCH_des.json` (events/sec, wall time, plus the headline
//! fig09 goodput and fig11b violation numbers) via `util::bench::
//! emit_json`, and gates against `benches/baselines/` when a checked-in
//! baseline exists: >20% events/sec regression fails the run (CI runs
//! `--smoke`; see `make bench-perf`).
//!
//! Accepts `--smoke` (see `util::bench::smoke`): ~40k requests instead
//! of 10M, same code paths, same artifact shape.

use std::time::Instant;

use harmonia::profile::models::zipf_hit_rate;
use harmonia::profile::{GenBatching, GenPlacement};
use harmonia::sched::SchedConfig;
use harmonia::sim::{run_point, SimConfig, SimResult, SimWorld, SystemKind};
use harmonia::spec::{apps, PipelineGraph};
use harmonia::util::bench::{emit_json, json_number_field, smoke, Json};
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

const SEED: u64 = 0xDE5_BE;
const SLO: f64 = 2.0;
/// Regression gate: fail when events/sec drops below this fraction of
/// the checked-in baseline.
const GATE_FRAC: f64 = 0.8;

struct WorkloadRun {
    name: &'static str,
    requests: usize,
    result: SimResult,
    wall_secs: f64,
}

fn timed(name: &'static str, requests: usize, graph: PipelineGraph, cfg: SimConfig) -> WorkloadRun {
    let t0 = Instant::now();
    let result = SimWorld::simulate(graph, cfg);
    let wall_secs = t0.elapsed().as_secs_f64();
    WorkloadRun { name, requests, result, wall_secs }
}

fn cfg_for(rate: f64, n: usize) -> SimConfig {
    let trace = TraceConfig { rate, n, slo: Some(SLO), ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    // The full traces span days of simulated time; don't let the
    // default 1-hour horizon truncate them.
    cfg.max_sim_time = 1e9;
    cfg
}

fn workloads(smoke: bool) -> Vec<WorkloadRun> {
    let scale = |full: usize, quick: usize| if smoke { quick } else { full };

    // 1. v-rag-cached: Zipf(1.1) request cache in front of retrieval.
    let n1 = scale(5_000_000, 20_000);
    let w1 = timed(
        "v-rag-cached",
        n1,
        apps::cached_vanilla_rag(1.1, 0.8, 512, 1024),
        cfg_for(600.0, n1),
    );

    // 2. hybrid-rag: sparse+dense fork/join on every request.
    let n2 = scale(2_000_000, 8_000);
    let w2 = timed("hybrid-rag", n2, apps::hybrid_rag(), cfg_for(64.0, n2));

    // 3. disaggregated generator + Zipf KV prefix cache.
    let n3 = scale(3_000_000, 12_000);
    let mut cfg = cfg_for(600.0, n3);
    cfg.trace.k_lo = 50;
    cfg.trace.k_hi = 100;
    cfg.gen_batching = GenBatching::Continuous;
    cfg.gen_placement = GenPlacement::Disaggregated;
    cfg.kv_prefix_hit_rate = zipf_hit_rate(1.3, 0.9, 4096, 2048);
    let w3 = timed("disagg-zipf", n3, apps::vanilla_rag(), cfg);

    vec![w1, w2, w3]
}

/// Headline fig09 point: Harmonia vs baselines on c-rag at one
/// operating rate (the paper's throughput claim, pinned by
/// `harmonia_beats_baselines_on_complex_pipeline_at_load`).
fn fig09_headline(smoke: bool) -> Json {
    let rate = 48.0;
    let n = if smoke { 600 } else { 5_000 };
    let h = run_point(SystemKind::Harmonia, apps::corrective_rag(), rate, n, None, 7);
    let l = run_point(SystemKind::LangChain, apps::corrective_rag(), rate, n, None, 7);
    let y = run_point(SystemKind::Haystack, apps::corrective_rag(), rate, n, None, 7);
    let best = l.report.goodput().max(y.report.goodput());
    Json::obj(vec![
        ("app", Json::Str("c-rag".into())),
        ("rate", Json::Num(rate)),
        ("requests", Json::Int(n as i64)),
        ("harmonia_goodput", Json::Num(h.report.goodput())),
        ("langchain_goodput", Json::Num(l.report.goodput())),
        ("haystack_goodput", Json::Num(y.report.goodput())),
        ("speedup_vs_best_baseline", Json::Num(h.report.goodput() / best.max(1e-9))),
    ])
}

/// Headline fig11b point: v-rag at 2x capacity, EDF alone vs the full
/// overload defense (admission + degradation) — SLO violations and
/// goodput for both arms.
fn fig11b_headline(smoke: bool) -> Json {
    let capacity = 730.0;
    let rate = capacity * 2.0;
    let n = if smoke { 2_000 } else { 8_000 };
    let run = |sched: SchedConfig| {
        let trace = TraceConfig { rate, n, slo: Some(SLO), ..TraceConfig::default() };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
        cfg.ablation.slo_sched = true;
        cfg.sched = sched;
        SimWorld::simulate(apps::vanilla_rag(), cfg)
    };
    let edf = run(SchedConfig::default());
    let def = run(SchedConfig::overload_defense());
    Json::obj(vec![
        ("app", Json::Str("v-rag".into())),
        ("rate", Json::Num(rate)),
        ("slo_s", Json::Num(SLO)),
        ("requests", Json::Int(n as i64)),
        ("edf_violation_pct", Json::Num(edf.report.slo_violation_rate * 100.0)),
        ("edf_goodput", Json::Num(edf.report.goodput())),
        ("defense_violation_pct", Json::Num(def.report.slo_violation_rate * 100.0)),
        ("defense_goodput", Json::Num(def.report.goodput())),
        ("defense_shed", Json::Int(def.report.shed as i64)),
    ])
}

/// `BENCH_des.json` lands next to the manifest (or `$BENCH_OUT_DIR`);
/// the smoke baseline lives under `benches/baselines/`.
fn out_path() -> std::path::PathBuf {
    let dir = std::env::var("BENCH_OUT_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    std::path::Path::new(&dir).join("BENCH_des.json")
}

fn baseline_path(smoke: bool) -> std::path::PathBuf {
    let file = if smoke { "BENCH_des.smoke.json" } else { "BENCH_des.json" };
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/baselines").join(file)
}

fn main() {
    let smoke = smoke();
    println!(
        "DES core perf: calendar-queue event loop, {} requests total{}\n",
        if smoke { "~40k" } else { "10M" },
        if smoke { " (--smoke)" } else { "" }
    );

    let runs = workloads(smoke);

    let mut t = Table::new(
        "per-workload event-loop throughput",
        &["workload", "requests", "events", "wall (s)", "events/sec", "goodput/s", "p99 (s)"],
    );
    let mut total_events = 0u64;
    let mut total_requests = 0usize;
    let mut total_wall = 0.0f64;
    let mut total_clamped = 0u64;
    let mut workload_rows = Vec::new();
    for w in &runs {
        let r = &w.result;
        let eps = r.events as f64 / w.wall_secs.max(1e-9);
        total_events += r.events;
        total_requests += w.requests;
        total_wall += w.wall_secs;
        total_clamped += r.clamped;
        t.row(&[
            w.name.to_string(),
            w.requests.to_string(),
            r.events.to_string(),
            f(w.wall_secs, 3),
            f(eps, 0),
            f(r.report.goodput(), 1),
            f(r.report.p99, 3),
        ]);
        workload_rows.push(Json::obj(vec![
            ("name", Json::Str(w.name.into())),
            ("requests", Json::Int(w.requests as i64)),
            ("completed", Json::Int(r.report.completed as i64)),
            ("events", Json::Int(r.events as i64)),
            ("wall_secs", Json::Num(w.wall_secs)),
            ("events_per_sec", Json::Num(eps)),
            ("throughput", Json::Num(r.report.throughput)),
            ("goodput", Json::Num(r.report.goodput())),
            ("p99_s", Json::Num(r.report.p99)),
            ("clamped", Json::Int(r.clamped as i64)),
        ]));
        // Hard invariants, not shape checks: every request completes
        // and no healthy model ever schedules into the past.
        assert_eq!(r.report.completed as usize, w.requests, "{}: dropped requests", w.name);
        assert_eq!(r.clamped, 0, "{}: model scheduled into the past", w.name);
    }
    t.print();
    let total_eps = total_events as f64 / total_wall.max(1e-9);
    println!(
        "\ntotal: {total_requests} requests, {total_events} events in {} — {} events/sec\n",
        f(total_wall, 2),
        f(total_eps, 0)
    );

    println!("headline metrics (fig09 / fig11b operating points)...");
    let fig09 = fig09_headline(smoke);
    let fig11b = fig11b_headline(smoke);

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_des".into())),
        ("smoke", Json::Bool(smoke)),
        ("total_requests", Json::Int(total_requests as i64)),
        ("total_events", Json::Int(total_events as i64)),
        ("total_wall_secs", Json::Num(total_wall)),
        ("total_events_per_sec", Json::Num(total_eps)),
        ("total_clamped", Json::Int(total_clamped as i64)),
        ("workloads", Json::Arr(workload_rows)),
        ("fig09", fig09),
        ("fig11b", fig11b),
    ]);
    let path = out_path();
    emit_json(&path, &doc).expect("write BENCH_des.json");
    // Self-check: the artifact must be machine-readable by the same
    // parser the regression gate uses.
    let text = std::fs::read_to_string(&path).expect("re-read artifact");
    for key in ["total_events_per_sec", "speedup_vs_best_baseline", "defense_violation_pct"] {
        assert!(
            json_number_field(&text, key).is_some(),
            "emitted BENCH_des.json is missing a readable {key}"
        );
    }
    println!("wrote {}", path.display());

    // Regression gate: only once a baseline is checked in.
    let base = baseline_path(smoke);
    match std::fs::read_to_string(&base) {
        Ok(btext) => match json_number_field(&btext, "total_events_per_sec") {
            Some(bline) if bline > 0.0 => {
                let ratio = total_eps / bline;
                println!(
                    "baseline {}: {} events/sec -> ratio {}",
                    base.display(),
                    f(bline, 0),
                    f(ratio, 3)
                );
                if ratio < GATE_FRAC {
                    eprintln!(
                        "REGRESSION: events/sec fell to {}x of baseline (gate {GATE_FRAC}x)",
                        f(ratio, 3)
                    );
                    std::process::exit(1);
                }
            }
            _ => println!("baseline {} unreadable; gate skipped", base.display()),
        },
        Err(_) => println!(
            "no checked-in baseline at {} yet; gate skipped (record one in a cargo-equipped env)",
            base.display()
        ),
    }
}
