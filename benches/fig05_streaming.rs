//! Figure 5 — Impact of Streaming: streaming improves performance at low
//! load (paper: >11%) but degrades it at high load (paper: −24%
//! performance / −36% throughput) when unmanaged.
//!
//! Also shows Harmonia's managed granularity recovering the best of both
//! (the §3.3.1 mechanism Fig. 14 ablates).

use harmonia::coordinator::StreamingMode;
use harmonia::sim::{SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

fn run(rate: f64, streaming: StreamingMode, managed: bool, seed: u64) -> (f64, f64) {
    // Generation-heavy V-RAG (the paper's LLM-dominant configuration):
    // median ~100 output tokens makes the generator the binding stage, so
    // chunk preemption has something to stall.
    let trace = TraceConfig {
        rate,
        n: (rate as usize * 20).max(2000),
        slo: None,
        gen_mu: 4.6,
        gen_sigma: 0.3,
        ..TraceConfig::default()
    };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, seed);
    cfg.streaming = streaming;
    cfg.ablation.stream_mgmt = managed;
    cfg.ablation.realloc = false; // isolate the streaming effect
    cfg.profile_bias = 1.0;
    let r = SimWorld::simulate(apps::vanilla_rag(), cfg);
    (r.report.throughput, r.report.mean_latency)
}

fn main() {
    println!("Figure 5 reproduction: streaming impact on V-RAG vs load\n");
    let seed = 0xF16_5;
    // The gen-heavy V-RAG saturates around ~450 req/s on the simulated
    // testbed; "high" must sit near capacity for the stall to bind
    // (Fig. 5's high load is near saturation too).
    let loads = [("low", 32.0), ("medium", 250.0), ("high", 430.0)];

    let mut t = Table::new(
        "V-RAG: streaming impact",
        &[
            "load",
            "rate",
            "thr off",
            "thr stream",
            "thr managed",
            "Δstream vs off",
            "lat off (s)",
            "lat stream (s)",
        ],
    );
    let mut low_gain = 0.0;
    let mut high_loss = 0.0;
    for (label, rate) in loads {
        let (thr_off, lat_off) = run(rate, StreamingMode::Off, false, seed);
        let (thr_fix, lat_fix) = run(rate, StreamingMode::FixedChunk(0.15), false, seed);
        let (thr_mgd, _lat_mgd) = run(rate, StreamingMode::Off, true, seed); // managed supersedes
        let delta = (thr_fix / thr_off - 1.0) * 100.0;
        if label == "low" {
            // At low load throughput is arrival-bound; the latency win is
            // the "performance" the paper reports.
            low_gain = (lat_off / lat_fix - 1.0) * 100.0;
        }
        if label == "high" {
            high_loss = (1.0 - thr_fix / thr_off) * 100.0;
        }
        t.row(&[
            label.to_string(),
            f(rate, 0),
            f(thr_off, 2),
            f(thr_fix, 2),
            f(thr_mgd, 2),
            format!("{}%", f(delta, 1)),
            f(lat_off, 3),
            f(lat_fix, 3),
        ]);
    }
    t.print();
    println!(
        "\nlow-load latency improvement from streaming: {}% (paper: >11%)",
        f(low_gain, 1)
    );
    println!(
        "high-load throughput degradation from unmanaged streaming: {}% (paper: 24–36%)",
        f(high_loss, 1)
    );
    println!(
        "SHAPE CHECK: streaming helps at low load ({}) and hurts at high load ({})",
        if low_gain > 3.0 { "yes — REPRODUCED" } else { "no" },
        if high_loss > 5.0 { "yes — REPRODUCED" } else { "no" },
    );
}
