//! Figure 12 — Scalability of the allocation LP: solve latency vs the
//! number of cluster nodes, for a 16-component RAG application.
//!
//! Paper's claim (Gurobi): 3.8–31.3 ms from small clusters up to 1024
//! nodes; our in-crate simplex must land in the same regime. Cluster size
//! enters through the resource budgets (the LP's variable count depends
//! on components, not machines — which is exactly why it stays fast).

use harmonia::alloc::FlowProblem;
use harmonia::profile::profile_graph;
use harmonia::spec::{ComponentKind, PipelineBuilder, ResourceKind};
use harmonia::util::bench::{bench, fmt_time};
use harmonia::util::table::Table;

/// A 16-component pipeline: classifier → 5 parallel branches of
/// retrieve→grade→generate, like a production multi-index RAG.
fn sixteen_component_app() -> harmonia::spec::PipelineGraph {
    let mut b = PipelineBuilder::new("16-comp");
    let cls = b.component("classifier", ComponentKind::Classifier).add();
    b.edge_from_source(cls, 1.0);
    let mut arms = Vec::new();
    for i in 0..5 {
        let r = b
            .component(&format!("retriever{i}"), ComponentKind::Retriever)
            .resources(&[(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)])
            .add();
        let g = b.component(&format!("grader{i}"), ComponentKind::Grader).add();
        let gen = b.component(&format!("generator{i}"), ComponentKind::Generator).add();
        b.edge(r, g, 1.0);
        b.edge(g, gen, 1.0);
        b.edge_to_sink(gen, 1.0);
        arms.push(r);
    }
    let p = 1.0 / arms.len() as f64;
    for r in arms {
        b.edge(cls, r, p);
    }
    b.build().expect("valid")
}

fn main() {
    println!("Figure 12 reproduction: allocation-LP solve latency vs cluster nodes\n");
    let graph = sixteen_component_app();
    assert_eq!(graph.work_nodes().count(), 16);
    let profile = profile_graph(&graph, 2000, 0xF16_12);

    let mut t = Table::new(
        "LP solve latency (16-component app)",
        &["cluster nodes", "mean", "p95", "pivots"],
    );
    let mut worst = 0.0f64;
    for nodes in [4usize, 16, 64, 256, 1024] {
        let budgets = vec![
            (ResourceKind::Cpu, 32.0 * nodes as f64),
            (ResourceKind::Gpu, 8.0 * nodes as f64),
            (ResourceKind::Ram, 256.0 * nodes as f64),
        ];
        let problem = FlowProblem::new(&graph, &profile, budgets.clone());
        let plan = problem.solve().expect("feasible");
        let stats = bench(&format!("solve-{nodes}"), 3, 20, 0.3, || {
            let p = FlowProblem::new(&graph, &profile, budgets.clone());
            let _ = harmonia::util::bench::black_box(p.solve().unwrap());
        });
        worst = worst.max(stats.p95);
        t.row(&[
            nodes.to_string(),
            fmt_time(stats.mean),
            fmt_time(stats.p95),
            plan.pivots.to_string(),
        ]);
    }
    t.print();
    println!("\npaper: 3.8–31.3 ms up to 1024 nodes (Gurobi)");
    println!(
        "SHAPE CHECK: worst p95 {} < 35 ms → suitable for 10-s re-solve loops: {}",
        fmt_time(worst),
        if worst < 35e-3 { "REPRODUCED" } else { "NOT reproduced" }
    );
}
