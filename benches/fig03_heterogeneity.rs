//! Figure 3 — Performance Heterogeneity: average time spent in each
//! component across the four RAG workflows under identical load and
//! dataset.
//!
//! Paper's claim: the bottleneck is a moving target; retrieval accounts
//! for anywhere from ~18% to ~62% of end-to-end time depending on the
//! workflow topology.

use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::apps;
use harmonia::util::table::{f, Table};

fn main() {
    let rate = 8.0; // identical moderate load for all workflows
    let n = 1500;
    println!("Figure 3 reproduction: per-component time share at {rate} req/s, {n} requests\n");

    let mut retrieval_shares = Vec::new();
    for graph in apps::all() {
        let name = graph.name.clone();
        let r = run_point(SystemKind::Harmonia, graph, rate, n, None, 0xF16_3);
        let total: f64 = r.report.components.values().map(|c| c.busy_time).sum();
        let mut rows: Vec<(String, f64)> = r
            .report
            .components
            .iter()
            .map(|(k, v)| (k.clone(), v.busy_time / total))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let retrieval: f64 = rows
            .iter()
            .filter(|(k, _)| k.contains("retriever"))
            .map(|(_, s)| s)
            .sum();
        retrieval_shares.push((name.clone(), retrieval));

        let mut t = Table::new(&format!("{name}: component time share"), &["component", "share %"]);
        for (k, s) in rows {
            t.row(&[k, f(100.0 * s, 1)]);
        }
        t.print();
        println!("  retrieval total: {}%\n", f(100.0 * retrieval, 1));
    }

    let mut t = Table::new("retrieval share across workflows (paper: 18%–62%)", &["workflow", "retrieval %"]);
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for (name, s) in &retrieval_shares {
        lo = lo.min(*s);
        hi = hi.max(*s);
        t.row(&[name.clone(), f(100.0 * s, 1)]);
    }
    t.print();
    println!(
        "\nSHAPE CHECK: retrieval share spans {}%–{}% across workflows (paper: 18%–62%) → bottleneck is a moving target: {}",
        f(100.0 * lo, 1),
        f(100.0 * hi, 1),
        if hi / lo.max(1e-9) > 1.8 { "REPRODUCED" } else { "NOT reproduced" }
    );
}
