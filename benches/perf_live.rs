//! Live serving-path performance — the first *measured* number for the
//! controller hot loop (the live counterpart of `perf_des.rs`).
//!
//! Deploys real pipelines (`v-rag-cached`, `hybrid-rag`) onto real
//! worker threads with the deterministic **echo engine**
//! (`ControllerConfig::echo`): no XLA artifacts, no model weights, but
//! the genuine retrieval index, caches, routing, admission plumbing,
//! fork/join barriers, and the zero-copy `RagState` hand-off. A
//! closed-loop driver (N client threads, one outstanding request each)
//! pushes a fixed request count through each app and reports:
//!
//!   - requests/sec (headline + regression gate key, v-rag-cached);
//!   - client-observed p50/p99 end-to-end latency;
//!   - per-hop controller dispatch overhead and busy fraction, straight
//!     from `RunReport::ctrl` (`metrics::CtrlStats`);
//!   - allocations per dispatch when built with
//!     `--features count-alloc` (a counting global allocator; `null` in
//!     the artifact otherwise).
//!
//! Emits `BENCH_live.json` via `util::bench::emit_json` and gates
//! against `benches/baselines/` when a baseline is checked in: >20%
//! requests/sec regression fails the run (CI runs `--smoke`; see
//! `make bench-live`).
//!
//! Accepts `--smoke` (see `util::bench::smoke`): a smaller corpus and
//! request count, same code paths, same artifact shape.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use harmonia::coordinator::controller::{deploy, ControllerConfig};
use harmonia::spec::apps;
use harmonia::spec::PipelineGraph;
use harmonia::util::bench::{emit_json, json_number_field, smoke, smoke_scale, Json};
use harmonia::util::table::{f, Table};

/// Counting global allocator: every `alloc`/`realloc` bumps a counter,
/// so the artifact can report allocations per dispatched hop. Opt-in
/// (`--features count-alloc`) because counting taxes every allocation
/// in the process — throughput numbers from a counting build are not
/// comparable with a stock build.
#[cfg(feature = "count-alloc")]
mod count_alloc {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub struct Counting;

    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static COUNTING: Counting = Counting;
}

fn alloc_count() -> Option<u64> {
    #[cfg(feature = "count-alloc")]
    {
        Some(count_alloc::ALLOCS.load(std::sync::atomic::Ordering::Relaxed))
    }
    #[cfg(not(feature = "count-alloc"))]
    {
        None
    }
}

const SEED: u64 = 0x11FE_2026;
/// Regression gate: fail when requests/sec drops below this fraction of
/// the checked-in baseline.
const GATE_FRAC: f64 = 0.8;

/// Sorted-sample percentile (nearest-rank on the sorted slice).
fn pct(sorted: &[f64], p: f64) -> f64 {
    sorted[((sorted.len() as f64 - 1.0) * p) as usize]
}

struct AppRun {
    name: &'static str,
    requests: usize,
    wall_secs: f64,
    requests_per_sec: f64,
    p50_s: f64,
    p99_s: f64,
    hops: u64,
    dispatch_ns_per_hop: f64,
    busy_frac: f64,
    allocs_per_dispatch: Option<f64>,
}

/// Closed-loop run: `clients` driver threads share a work counter, each
/// keeps exactly one request outstanding. Dispatch overhead and the
/// alloc count are deltas across the timed window only (warmup and
/// deploy excluded), read from two `RunReport::ctrl` snapshots.
fn run_app(
    name: &'static str,
    graph: PipelineGraph,
    corpus_size: usize,
    total: usize,
    clients: usize,
    warmup: usize,
) -> AppRun {
    let mut cfg = ControllerConfig::echo(SEED);
    cfg.corpus_size = corpus_size;
    let h = deploy(graph, cfg).expect("deploy echo pipeline");

    for i in 0..warmup {
        let q = format!("warmup query {i} topic {}", i % 17);
        let r = h.submit(q.as_bytes()).recv().expect("warmup response");
        assert!(r.error.is_none(), "warmup request failed: {:?}", r.error);
    }

    let ctrl0 = h.report().ctrl.expect("live run attaches ctrl stats");
    let allocs0 = alloc_count();
    let next = AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(total);
    let mut hops: u64 = 0;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let client = h.client();
                let next = &next;
                s.spawn(move || {
                    let mut lats: Vec<f64> = Vec::new();
                    let mut hops: u64 = 0;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= total {
                            break;
                        }
                        let q = format!("live bench query {i} topic {}", i % 17);
                        let sent = Instant::now();
                        let r = client.submit(q.as_bytes()).recv().expect("live response");
                        lats.push(sent.elapsed().as_secs_f64());
                        assert!(r.error.is_none(), "request {i} failed: {:?}", r.error);
                        assert!(!r.answer.is_empty(), "request {i} returned an empty answer");
                        hops += r.hops as u64;
                    }
                    (lats, hops)
                })
            })
            .collect();
        for handle in handles {
            let (lats, h2) = handle.join().expect("client thread");
            latencies.extend(lats);
            hops += h2;
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let allocs1 = alloc_count();
    let rep = h.report();
    let ctrl1 = rep.ctrl.expect("live run attaches ctrl stats");
    h.shutdown();

    assert_eq!(latencies.len(), total, "{name}: every request must complete");
    assert_eq!(rep.shed, 0, "{name}: default config admits everything");
    latencies.sort_by(f64::total_cmp);
    let dispatches = ctrl1.dispatches - ctrl0.dispatches;
    let dispatch_secs = ctrl1.dispatch_secs - ctrl0.dispatch_secs;
    AppRun {
        name,
        requests: total,
        wall_secs: wall,
        requests_per_sec: total as f64 / wall.max(1e-12),
        p50_s: pct(&latencies, 0.50),
        p99_s: pct(&latencies, 0.99),
        hops,
        dispatch_ns_per_hop: if dispatches == 0 {
            0.0
        } else {
            dispatch_secs / dispatches as f64 * 1e9
        },
        busy_frac: ctrl1.busy_frac(),
        allocs_per_dispatch: match (allocs0, allocs1) {
            (Some(a0), Some(a1)) if dispatches > 0 => {
                Some((a1 - a0) as f64 / dispatches as f64)
            }
            _ => None,
        },
    }
}

fn out_path() -> std::path::PathBuf {
    let dir = std::env::var("BENCH_OUT_DIR")
        .unwrap_or_else(|_| env!("CARGO_MANIFEST_DIR").to_string());
    std::path::Path::new(&dir).join("BENCH_live.json")
}

fn baseline_path(smoke: bool) -> std::path::PathBuf {
    let file = if smoke { "BENCH_live.smoke.json" } else { "BENCH_live.json" };
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/baselines").join(file)
}

fn main() {
    let smoke = smoke();
    let corpus_size = smoke_scale(4096, 512);
    let total = smoke_scale(2000, 200);
    let clients = smoke_scale(8, 4);
    let warmup = smoke_scale(64, 16);
    println!(
        "live serving-path perf (echo engine): corpus={corpus_size} requests={total} clients={clients}{}{}\n",
        if smoke { " (--smoke)" } else { "" },
        if alloc_count().is_some() { " [count-alloc]" } else { "" },
    );

    let runs = [
        run_app("v-rag-cached", apps::vanilla_rag(), corpus_size, total, clients, warmup),
        run_app("hybrid-rag", apps::hybrid_rag(), corpus_size, total, clients, warmup),
    ];

    let mut t = Table::new(
        "closed-loop serving",
        &["app", "req/s", "p50 (ms)", "p99 (ms)", "hops", "dispatch ns/hop", "busy", "allocs/hop"],
    );
    for r in &runs {
        t.row(&[
            r.name.to_string(),
            f(r.requests_per_sec, 0),
            f(r.p50_s * 1e3, 2),
            f(r.p99_s * 1e3, 2),
            r.hops.to_string(),
            f(r.dispatch_ns_per_hop, 0),
            f(r.busy_frac, 3),
            r.allocs_per_dispatch.map(|a| f(a, 1)).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    let headline = &runs[0];
    let run_json = |r: &AppRun| {
        Json::obj(vec![
            ("app", Json::Str(r.name.into())),
            ("requests", Json::Int(r.requests as i64)),
            ("wall_secs", Json::Num(r.wall_secs)),
            ("requests_per_sec", Json::Num(r.requests_per_sec)),
            ("p50_s", Json::Num(r.p50_s)),
            ("p99_s", Json::Num(r.p99_s)),
            ("hops", Json::Int(r.hops as i64)),
            ("dispatch_ns_per_hop", Json::Num(r.dispatch_ns_per_hop)),
            ("busy_frac", Json::Num(r.busy_frac)),
            (
                "allocs_per_dispatch",
                r.allocs_per_dispatch.map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_live".into())),
        ("smoke", Json::Bool(smoke)),
        ("corpus_n", Json::Int(corpus_size as i64)),
        ("requests", Json::Int(total as i64)),
        ("clients", Json::Int(clients as i64)),
        ("count_alloc", Json::Bool(alloc_count().is_some())),
        // Headline + gate key: v-rag-cached closed-loop requests/sec.
        ("requests_per_sec", Json::Num(headline.requests_per_sec)),
        ("dispatch_ns_per_hop", Json::Num(headline.dispatch_ns_per_hop)),
        ("p50_s", Json::Num(headline.p50_s)),
        ("p99_s", Json::Num(headline.p99_s)),
        ("apps", Json::Arr(runs.iter().map(run_json).collect())),
    ]);
    let path = out_path();
    emit_json(&path, &doc).expect("write BENCH_live.json");
    // Self-check: the artifact must be machine-readable by the same
    // parser the regression gate uses.
    let text = std::fs::read_to_string(&path).expect("re-read artifact");
    for key in ["requests_per_sec", "dispatch_ns_per_hop", "p50_s", "p99_s"] {
        assert!(
            json_number_field(&text, key).is_some(),
            "emitted BENCH_live.json is missing a readable {key}"
        );
    }
    println!("\nwrote {}", path.display());

    // Regression gate: only once a baseline is checked in.
    let base = baseline_path(smoke);
    match std::fs::read_to_string(&base) {
        Ok(btext) => match json_number_field(&btext, "requests_per_sec") {
            Some(bline) if bline > 0.0 => {
                let ratio = headline.requests_per_sec / bline;
                println!(
                    "baseline {}: {} req/s -> ratio {}",
                    base.display(),
                    f(bline, 0),
                    f(ratio, 3)
                );
                if ratio < GATE_FRAC {
                    eprintln!(
                        "REGRESSION: requests/sec fell to {}x of baseline (gate {GATE_FRAC}x)",
                        f(ratio, 3)
                    );
                    std::process::exit(1);
                }
            }
            _ => println!("baseline {} unreadable; gate skipped", base.display()),
        },
        Err(_) => println!(
            "no checked-in baseline at {} yet; gate skipped (record one in a cargo-equipped env)",
            base.display()
        ),
    }
}
