//! Table 3 — Co-location: a CPU-heavy retriever and a GPU-heavy
//! generator sharing a node interfere by < 1.1% (paper: ChromaDB 971.9 vs
//! 972.3 ops/s; vLLM 127.6 vs 128.3 req/s).
//!
//! Live measurement: the IVF retriever (CPU scoring, paced at a fixed
//! offered load — co-location means both components run within their own
//! resource budgets) and the XLA decode loop run with the retriever load
//! toggled on/off in interleaved A/B windows. Interleaving + medians
//! cancel this container's CPU-quota throttling drift, which otherwise
//! swamps the comparison (sustained decode throughput decays ~5× after a
//! few seconds regardless of co-location). Falls back to the simulator's
//! co-location model when artifacts are absent.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use harmonia::retrieval::{IvfIndex, IvfParams};
use harmonia::runtime::generator::{GenRequest, Generator};
use harmonia::runtime::{artifacts_available, default_artifacts_dir};
use harmonia::util::table::{f, Table};
use harmonia::workload::Corpus;

fn median(xs: &mut Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    println!("Table 3 reproduction: co-location interference (retriever + generator)\n");
    if !artifacts_available() {
        println!("artifacts not built (`make artifacts`); run skipped.");
        println!("The simulator models this via COLOCATION_SLOWDOWN = 1.005 (< the paper's 1.1%).");
        return;
    }

    // Retrieval fixture.
    let dim = 64;
    let n = 20_000;
    let corpus = Corpus::generate(n, 32, 64, 3);
    let mut vectors = Vec::with_capacity(n * dim);
    for p in &corpus.passages {
        vectors.extend(Corpus::hash_embed(&p.text, dim));
    }
    let index = Arc::new(IvfIndex::build(vectors, dim, IvfParams::default()));
    let queries: Vec<Vec<f32>> =
        (0..64).map(|i| Corpus::hash_embed(format!("query {i}").as_bytes(), dim)).collect();

    // Persistent retriever thread serving a fixed offered load whenever
    // `active` is set (1000 q/s — the paper's ChromaDB served ~970 ops/s).
    let active = Arc::new(AtomicBool::new(false));
    let stop = Arc::new(AtomicBool::new(false));
    let retr_lat = Arc::new(std::sync::Mutex::new((Vec::new(), Vec::new()))); // (iso, colo) — colo used
    let (idx2, q2, active2, stop2, lat2) =
        (index.clone(), queries.clone(), active.clone(), stop.clone(), retr_lat.clone());
    let retr_thread = std::thread::spawn(move || {
        let rate = 1000.0;
        let mut i = 0usize;
        let mut ops = 0u64;
        let t0 = Instant::now();
        while !stop2.load(Ordering::Relaxed) {
            if !active2.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            let due = ops as f64 / rate;
            let now = t0.elapsed().as_secs_f64();
            if due > now {
                std::thread::sleep(Duration::from_secs_f64((due - now).min(0.002)));
                continue;
            }
            let s0 = Instant::now();
            std::hint::black_box(idx2.search(&q2[i % q2.len()], 10, 512));
            lat2.lock().unwrap().1.push(s0.elapsed().as_secs_f64());
            ops += 1;
            i += 1;
        }
    });

    // Retriever baseline latency, isolated (main thread, before engines).
    {
        let mut iso = Vec::new();
        for i in 0..2000 {
            let s0 = Instant::now();
            std::hint::black_box(index.search(&queries[i % queries.len()], 10, 512));
            iso.push(s0.elapsed().as_secs_f64());
        }
        retr_lat.lock().unwrap().0 = iso;
    }

    // Generator: interleaved A/B windows of per-batch latency.
    let g = Generator::new(&default_artifacts_dir()).expect("generator");
    let reqs: Vec<GenRequest> =
        (0..4).map(|i| GenRequest::greedy(format!("colocation probe {i}").as_bytes(), 8)).collect();
    let _ = g.generate_batch(&reqs, |_, _| {}).unwrap(); // warm
    let mut iso_meds = Vec::new();
    let mut colo_meds = Vec::new();
    for round in 0..8 {
        let colocated = round % 2 == 1;
        active.store(colocated, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(50));
        let mut lats = Vec::new();
        let w0 = Instant::now();
        while w0.elapsed().as_secs_f64() < 0.8 {
            let s0 = Instant::now();
            let _ = g.generate_batch(&reqs, |_, _| {}).unwrap();
            lats.push(s0.elapsed().as_secs_f64());
        }
        let m = median(&mut lats);
        if colocated {
            colo_meds.push(m);
        } else {
            iso_meds.push(m);
        }
    }
    active.store(false, Ordering::Relaxed);
    stop.store(true, Ordering::Relaxed);
    retr_thread.join().unwrap();

    let gen_iso = 4.0 / median(&mut iso_meds);
    let gen_colo = 4.0 / median(&mut colo_meds);
    let (mut retr_iso_l, mut retr_colo_l) = {
        let l = retr_lat.lock().unwrap();
        (l.0.clone(), l.1.clone())
    };
    let retr_iso_lat = median(&mut retr_iso_l);
    let retr_colo_lat = median(&mut retr_colo_l);

    let gen_delta = (1.0 - gen_colo / gen_iso) * 100.0;
    let retr_delta = (retr_colo_lat / retr_iso_lat - 1.0) * 100.0;
    let mut t = Table::new(
        "isolated vs co-located (interleaved windows, medians)",
        &["component", "metric", "isolated", "colocated", "delta %"],
    );
    t.row(&[
        "retriever (IVF, CPU)".into(),
        "search latency (us)".into(),
        f(retr_iso_lat * 1e6, 1),
        f(retr_colo_lat * 1e6, 1),
        f(retr_delta, 2),
    ]);
    t.row(&[
        "generator (XLA decode)".into(),
        "throughput (req/s)".into(),
        f(gen_iso, 1),
        f(gen_colo, 1),
        f(gen_delta, 2),
    ]);
    t.print();
    println!("\npaper: < 1.1% throughput variance for both components");
    println!(
        "SHAPE CHECK: co-location within budgets costs each component <15% even \
         though our 'GPU' engine physically shares the CPU with the retriever \
         (the paper's <1.1% is between disjoint CPU and GPU silicon; the \
         simulator models that disjoint case as 0.5%): {}",
        if retr_delta.abs() < 15.0 && gen_delta.abs() < 15.0 {
            "REPRODUCED (scaled)"
        } else {
            "NOT reproduced"
        }
    );
}
