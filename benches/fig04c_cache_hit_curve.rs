//! Figure 4c (extension) — Request-cache hit curve: Zipfian workload
//! skew (`zipf_s`) × cache capacity → hit rate, retrieval-stage p50/p99,
//! and end-to-end DES latency with the cache-adjusted service model.
//!
//! The claim this bench pins down: retrieval capacity *grows* with load
//! skew — a cache tier in front of the embed→retrieve prefix turns the
//! hottest queries into O(1) probes, so the hotter the traffic, the less
//! scatter-gather work per admitted request. Cached results are
//! bit-identical to the uncached pass on exact repeats (also pinned by
//! property tests in `cache::query_cache`).
//!
//! The measured hit/miss latency ratio is the calibration target for
//! `profile::models::CACHE_HIT_COST_FRAC` (modeled at 5%).

use std::time::Instant;

use harmonia::cache::{CacheConfig, QueryCache};
use harmonia::retrieval::{IvfParams, ShardParams, ShardedIndex};
use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::apps;
use harmonia::stats::percentile::percentile;
use harmonia::util::bench::smoke_scale;
use harmonia::util::table::{f, Table};
use harmonia::workload::queries::{QueryMix, ZipfQueryGen};
use harmonia::workload::Corpus;

const DIM: usize = 64;
const K: usize = 10;
const SEARCH_EF: usize = 2048;
/// Queries per cached sweep point (shrunk under `--smoke` so CI can
/// execute the bench; see `util::bench::smoke`).
fn n_queries() -> usize {
    smoke_scale(4000, 800)
}
const POOL: usize = 1024;
const REPEAT_FRAC: f64 = 0.8;

struct Point {
    hit_rate: f64,
    p50_us: f64,
    p99_us: f64,
    mean_us: f64,
    exact_identical: bool,
}

/// Drive a Zipfian stream through cache + index; measure per-query
/// retrieval latency and verify exact-repeat identity against a fresh
/// search.
fn run_cached(
    index: &ShardedIndex,
    corpus: &Corpus,
    zipf_s: f64,
    cache_entries: usize,
    semantic_entries: usize,
) -> Point {
    let cache = QueryCache::new(CacheConfig {
        exact_capacity: cache_entries,
        semantic_capacity: semantic_entries,
        ttl: 1e9,
        sim_threshold: 0.95,
        n_shards: 8,
    });
    let mix = QueryMix { zipf_s, repeat_frac: REPEAT_FRAC, pool_size: POOL };
    let mut qg = ZipfQueryGen::new(corpus, mix, 0xF16_4C);
    let mut lats = Vec::with_capacity(n_queries());
    let mut exact_identical = true;
    for t in 0..n_queries() {
        let q = qg.next();
        let now = t as f64;
        let t0 = Instant::now();
        let (served, from_exact_tier) = match cache.lookup_exact(&q.text, now) {
            Some(hits) => (hits, true),
            None => {
                let emb = Corpus::hash_embed(&q.text, DIM);
                match cache.lookup_semantic(&emb, now) {
                    Some(hits) => (hits, false),
                    None => {
                        let fresh = index.search(&emb, K, SEARCH_EF);
                        cache.insert(&q.text, &emb, &fresh, now);
                        (fresh, false)
                    }
                }
            }
        };
        lats.push(t0.elapsed().as_secs_f64());
        // Identity audit (outside the timed section): an exact-tier hit
        // is a memoized repeat, so it must equal a recomputed search
        // bit-for-bit — the index is deterministic. Semantic hits are
        // approximate by design and are not audited.
        if from_exact_tier && t % 17 == 0 {
            let oracle = index.search(&Corpus::hash_embed(&q.text, DIM), K, SEARCH_EF);
            exact_identical &= served.len() == oracle.len()
                && served
                    .iter()
                    .zip(&oracle)
                    .all(|(a, b)| a.id == b.id && a.score == b.score);
        }
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let snap = cache.snapshot();
    Point {
        hit_rate: snap.hit_rate(),
        p50_us: percentile(&lats, 50.0) * 1e6,
        p99_us: percentile(&lats, 99.0) * 1e6,
        mean_us: lats.iter().sum::<f64>() / lats.len() as f64 * 1e6,
        exact_identical,
    }
}

fn main() {
    let n = smoke_scale(20_000, 5_000);
    println!(
        "Figure 4c: request-cache hit curve (corpus n={n}, d={DIM}, K={K}, \
         search_ef={SEARCH_EF}, pool={POOL}, repeat_frac={REPEAT_FRAC}, \
         {} queries)\n",
        n_queries()
    );

    let corpus = Corpus::generate(n, 64, 64, 0xF16_4C);
    let mut vectors = Vec::with_capacity(n * DIM);
    for p in &corpus.passages {
        vectors.extend(Corpus::hash_embed(&p.text, DIM));
    }
    let index = ShardedIndex::build(
        vectors,
        DIM,
        ShardParams {
            n_shards: 4,
            ivf: IvfParams { n_lists: 256, kmeans_iters: 6, seed: 1, ..IvfParams::default() },
        },
    );

    // Uncached baseline: every query pays embed + scatter-gather.
    let mix = QueryMix { zipf_s: 1.1, repeat_frac: REPEAT_FRAC, pool_size: POOL };
    let mut qg = ZipfQueryGen::new(&corpus, mix, 0xF16_4C);
    let mut base_lats: Vec<f64> = (0..n_queries())
        .map(|_| {
            let q = qg.next();
            let t0 = Instant::now();
            let emb = Corpus::hash_embed(&q.text, DIM);
            let _ = index.search(&emb, K, SEARCH_EF);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    base_lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let base_p50 = percentile(&base_lats, 50.0) * 1e6;

    // Sweep 1: skew at fixed capacity.
    let cache_entries = 512;
    let mut t1 = Table::new(
        "hit rate & retrieval latency vs zipf_s (cache=512 entries)",
        &["zipf_s", "hit rate", "p50 us", "p99 us", "mean us", "p50 speedup"],
    );
    let mut hit_rates = Vec::new();
    let mut p50_speedup_at_hot = 0.0;
    let mut all_identical = true;
    for zipf_s in [0.4, 0.8, 1.1, 1.4] {
        let pt = run_cached(&index, &corpus, zipf_s, cache_entries, cache_entries / 4);
        hit_rates.push(pt.hit_rate);
        if pt.hit_rate >= 0.5 {
            p50_speedup_at_hot = base_p50 / pt.p50_us;
        }
        all_identical &= pt.exact_identical;
        t1.row(&[
            f(zipf_s, 1),
            f(pt.hit_rate, 3),
            f(pt.p50_us, 1),
            f(pt.p99_us, 1),
            f(pt.mean_us, 1),
            format!("{}x", f(base_p50 / pt.p50_us, 2)),
        ]);
    }
    t1.print();

    // Sweep 2: capacity at fixed skew. Semantic tier OFF so the observed
    // rate is exact-repeat hits only — apples-to-apples with the
    // zipf_hit_rate model, which covers exact repeats.
    let mut t2 = Table::new(
        "hit rate vs cache capacity (zipf_s=1.1, exact tier only)",
        &["entries", "hit rate", "p50 us", "p99 us", "modeled hit (zipf_hit_rate)"],
    );
    for entries in [64usize, 256, 1024] {
        let pt = run_cached(&index, &corpus, 1.1, entries, 0);
        all_identical &= pt.exact_identical;
        t2.row(&[
            entries.to_string(),
            f(pt.hit_rate, 3),
            f(pt.p50_us, 1),
            f(pt.p99_us, 1),
            f(
                harmonia::profile::models::zipf_hit_rate(1.1, REPEAT_FRAC, POOL, entries),
                3,
            ),
        ]);
    }
    t2.print();

    // End-to-end: the DES with the cache-adjusted retrieval model.
    let mut t3 = Table::new(
        "end-to-end DES latency with cache-adjusted retrieval (V-RAG, 16 req/s)",
        &["app", "modeled hit", "p50 s", "p99 s", "throughput"],
    );
    let plain = run_point(SystemKind::Harmonia, apps::vanilla_rag(), 16.0, smoke_scale(800, 200), Some(2.0), 42);
    t3.row(&[
        "v-rag".into(),
        "0.000".into(),
        f(plain.report.p50, 3),
        f(plain.report.p99, 3),
        f(plain.report.throughput, 1),
    ]);
    for zipf_s in [0.8, 1.1, 1.4] {
        let g = apps::cached_vanilla_rag(zipf_s, REPEAT_FRAC, 512, POOL);
        let h = g.node_by_name("retriever").unwrap().cache_hit_rate;
        let r = run_point(SystemKind::Harmonia, g, 16.0, smoke_scale(800, 200), Some(2.0), 42);
        t3.row(&[
            format!("v-rag-cached s={zipf_s}"),
            f(h, 3),
            f(r.report.p50, 3),
            f(r.report.p99, 3),
            f(r.report.throughput, 1),
        ]);
    }
    t3.print();

    let monotone = hit_rates.windows(2).all(|w| w[1] >= w[0] - 0.02);
    println!(
        "\nSHAPE CHECK: hit rate grows with zipf_s ({}): {}",
        hit_rates.iter().map(|h| f(*h, 3)).collect::<Vec<_>>().join(" -> "),
        if monotone { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: p50 retrieval speedup at >=50% hit rate: {}x — {}",
        f(p50_speedup_at_hot, 2),
        if p50_speedup_at_hot > 1.0 { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: cached results bit-identical to uncached on exact repeats: {}",
        if all_identical { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "(calibration target for profile::models::CACHE_HIT_COST_FRAC — modeled {})",
        harmonia::profile::models::CACHE_HIT_COST_FRAC
    );
}
