//! Figure 13 — Controller processing latency: per-request decision time
//! must stay stable (~2 ms budget; measured ≤2.3 ms at 1024 req/s in the
//! paper) as load grows, because the controller is control-plane-only.
//!
//! We measure the *actual wall-clock time of the real routing+scheduling
//! code* per dispatch inside the simulator, across request rates; plus
//! the §4.3 distribution-layer overhead comparison vs single-node
//! function calls (paper: ≈0.8%).

use harmonia::sim::{run_point, SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::util::bench::fmt_time;
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

fn main() {
    println!("Figure 13 reproduction: controller decision latency vs request rate\n");
    let mut t = Table::new(
        "controller decision time per dispatch",
        &["request rate (req/s)", "decisions", "mean decision time"],
    );
    let mut worst = 0.0f64;
    for rate in [64.0, 128.0, 256.0, 512.0, 1024.0] {
        let n = (rate * 4.0) as usize; // ~4 seconds of traffic
        let r = run_point(SystemKind::Harmonia, apps::corrective_rag(), rate, n, None, 0xF16_13);
        worst = worst.max(r.controller_decision_secs);
        t.row(&[
            f(rate, 0),
            r.controller_decisions.to_string(),
            fmt_time(r.controller_decision_secs),
        ]);
    }
    t.print();
    println!("\npaper: scheduling latency stays below 2.3 ms at 1024 req/s");
    println!(
        "SHAPE CHECK: worst mean decision time {} < 2.3 ms: {}\n",
        fmt_time(worst),
        if worst < 2.3e-3 { "REPRODUCED" } else { "NOT reproduced" }
    );

    // §4.3 Overhead: distribution layer vs single-node function calls.
    println!("§4.3 overhead: Harmonia distribution layer vs in-process function calls");
    let trace = TraceConfig { rate: 8.0, n: 1000, slo: None, ..TraceConfig::default() };
    let mut with = SimConfig::new(SystemKind::Harmonia, trace.clone(), 1);
    with.profile_bias = 1.0;
    let mut without = with.clone();
    without.controller_overhead = 0.0;
    let a = SimWorld::simulate(apps::vanilla_rag(), with);
    let b = SimWorld::simulate(apps::vanilla_rag(), without);
    let overhead = (a.report.mean_latency / b.report.mean_latency - 1.0) * 100.0;
    println!(
        "  mean latency: {} s (dispatch overhead on) vs {} s (off) → {}% (paper: ≈0.8%)",
        f(a.report.mean_latency, 4),
        f(b.report.mean_latency, 4),
        f(overhead, 2)
    );
}
