//! Figure 9 — End-to-end throughput of the four RAG workflows under
//! Harmonia vs LangChain-like and Haystack-like baselines, across load.
//!
//! Paper's claims: V-RAG up to ~1.31× (narrowing to ~3% at saturation);
//! C-RAG up to 1.98×; S-RAG up to 2.04×; A-RAG up to 1.48×; average ~1.6×.

use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::apps;
use harmonia::util::table::{f, Table};

fn main() {
    println!("Figure 9 reproduction: throughput vs offered load (req/s)\n");
    let seed = 0xF16_9;
    let paper_max = [("v-rag", 1.31), ("c-rag", 1.98), ("s-rag", 2.04), ("a-rag", 1.48)];

    let mut summary = Vec::new();
    for (app, paper) in paper_max {
        // Sweep to each system's saturation regime (the paper's gaps open
        // near capacity).
        let rates: &[f64] = match app {
            "v-rag" => &[128.0, 256.0, 384.0, 512.0, 640.0, 760.0],
            _ => &[64.0, 128.0, 192.0, 256.0, 320.0, 400.0],
        };
        let mut t = Table::new(
            &format!("{app}: throughput (req/s)"),
            &["rate", "harmonia", "langchain", "haystack", "speedup vs best baseline"],
        );
        let mut max_speedup: f64 = 0.0;
        for &rate in rates {
            // Trace long enough for several 10-s reallocation rounds.
            let n = ((rate * 30.0) as usize).max(1500);
            let h = run_point(SystemKind::Harmonia, apps::by_name(app).unwrap(), rate, n, None, seed);
            let l = run_point(SystemKind::LangChain, apps::by_name(app).unwrap(), rate, n, None, seed);
            let y = run_point(SystemKind::Haystack, apps::by_name(app).unwrap(), rate, n, None, seed);
            let best = l.report.throughput.max(y.report.throughput);
            let speedup = h.report.throughput / best.max(1e-9);
            max_speedup = max_speedup.max(speedup);
            t.row(&[
                f(rate, 0),
                f(h.report.throughput, 2),
                f(l.report.throughput, 2),
                f(y.report.throughput, 2),
                format!("{}x", f(speedup, 2)),
            ]);
        }
        t.print();
        println!("  max speedup: {}x (paper: up to {}x)\n", f(max_speedup, 2), paper);
        summary.push((app, max_speedup, paper));
    }

    let mut t = Table::new("summary (paper Figure 9)", &["workflow", "max speedup", "paper"]);
    let mut reproduced = true;
    let mut avg = 0.0;
    for (app, got, paper) in &summary {
        avg += got;
        t.row(&[app.to_string(), format!("{}x", f(*got, 2)), format!("{}x", paper)]);
        if *got < 1.05 {
            reproduced = false;
        }
    }
    avg /= summary.len() as f64;
    t.print();
    println!("\naverage max speedup: {}x (paper avg: ~1.6x)", f(avg, 2));
    println!(
        "SHAPE CHECK: Harmonia wins on every workflow, complex pipelines win bigger: {}",
        if reproduced { "REPRODUCED" } else { "NOT reproduced" }
    );
}
