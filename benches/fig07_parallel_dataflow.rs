//! Figure 07 (extension) — Parallel dataflow (fork/join): hybrid
//! retrieval (dense ∥ web) and multi-query expansion vs their serialized
//! equivalents, at equal allocation.
//!
//! The claim this bench pins down: canonical RAG shapes — hybrid
//! retrieval and query expansion — contain stages with **no data
//! dependency** between them, and running them back to back puts their
//! sum on the critical path. Typed `Fork` edges overlap the independent
//! stages and a `JoinSpec` barrier fuses the results, so per-request
//! latency drops from Σ(branches) to max(branches) while the allocation
//! LP still provisions every branch at full flow (same resource bill,
//! RAGO-style TTFT win). The join barrier's sibling stall is reported
//! explicitly via the per-node breakdown table instead of folding into
//! end-to-end latency.
//!
//! Runs under `GenBatching::Continuous` so TTFT is measured at decode
//! granularity. Accepts `--smoke` (see `util::bench::smoke`) for CI.

use harmonia::profile::{graph_latency, profile_graph, GenBatching};
use harmonia::sim::{SimConfig, SimWorld, SystemKind};
use harmonia::spec::{apps, PipelineGraph};
use harmonia::util::bench::{smoke, smoke_scale};
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

const SLO: f64 = 2.0;
const SEED: u64 = 0xF16_07;

fn run(graph: PipelineGraph, rate: f64, n: usize) -> harmonia::sim::SimResult {
    let trace = TraceConfig { rate, n, slo: Some(SLO), ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.gen_batching = GenBatching::Continuous;
    SimWorld::simulate(graph, cfg)
}

fn main() {
    let n = smoke_scale(2000, 300);
    println!(
        "Figure 07: parallel dataflow (fork/join) vs serialized equivalents \
         (SLO = {SLO} s, n = {n}{})\n",
        if smoke() { ", --smoke" } else { "" }
    );

    // Modeled critical paths from the deploy-time profile: the latency
    // the fork should save before any queueing.
    for (name, par, seq) in [
        ("hybrid", apps::hybrid_rag(), apps::hybrid_rag_sequential()),
        ("multi-query(3)", apps::multiquery_rag(3), apps::multiquery_rag_sequential(3)),
    ] {
        let pp = profile_graph(&par, 2000, SEED);
        let ps = profile_graph(&seq, 2000, SEED);
        println!(
            "modeled critical path [{name}]: parallel {:.3} s vs serialized {:.3} s",
            graph_latency(&par, &pp.mean_service),
            graph_latency(&seq, &ps.mean_service),
        );
    }
    println!();

    let pairs: [(&str, fn() -> PipelineGraph, fn() -> PipelineGraph); 2] = [
        ("hybrid", apps::hybrid_rag, apps::hybrid_rag_sequential),
        ("multi-query(3)", || apps::multiquery_rag(3), || apps::multiquery_rag_sequential(3)),
    ];
    let rates = [16.0, 64.0];
    let mut p50_wins = true;
    let mut p99_wins = true;
    let mut ttft_wins = true;

    for (name, par_fn, seq_fn) in pairs {
        for &rate in &rates {
            let par = run(par_fn(), rate, n);
            let seq = run(seq_fn(), rate, n);
            let mut t = Table::new(
                &format!("{name} @ {} req/s", f(rate, 0)),
                &["shape", "p50 (s)", "p99 (s)", "TTFT p50", "TTFT p99", "goodput/s"],
            );
            for (shape, r) in [("parallel", &par), ("serialized", &seq)] {
                let g = r.report.gen.expect("continuous mode records TTFT");
                t.row(&[
                    shape.to_string(),
                    f(r.report.p50, 3),
                    f(r.report.p99, 3),
                    f(g.ttft_p50, 3),
                    f(g.ttft_p99, 3),
                    f(r.report.goodput(), 1),
                ]);
            }
            t.print();
            println!();
            let (gp, gs) = (par.report.gen.unwrap(), seq.report.gen.unwrap());
            p50_wins &= par.report.p50 < seq.report.p50;
            p99_wins &= par.report.p99 < seq.report.p99;
            ttft_wins &= gp.ttft_p50 < gs.ttft_p50 && gp.ttft_p99 < gs.ttft_p99;
            if rate == rates[0] {
                // Fork stall made visible: queue vs service vs join-wait.
                print!("{}", par.report.breakdown_table(&format!("{name} parallel breakdown")));
                println!();
            }
        }
    }

    println!(
        "SHAPE CHECK: parallel strictly cuts p50 vs serialized at every rate: {}",
        if p50_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: parallel strictly cuts p99 vs serialized at every rate: {}",
        if p99_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: parallel strictly cuts p50+p99 TTFT vs serialized: {}",
        if ttft_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
}
