//! Figure 10 — Component-Level Breakdown for C-RAG: the grader is the
//! bottleneck; Harmonia's allocation alleviates it (lower queueing).

use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::apps;
use harmonia::util::table::{f, Table};

fn main() {
    println!("Figure 10 reproduction: C-RAG component breakdown (service + queue)\n");
    // Near C-RAG saturation (our substrate's capacity region; the
    // paper's 40 req/s sat at the same relative utilization on A100s).
    let rate = 300.0;
    let n = 9000;
    let seed = 0xF16_10;

    let h = run_point(SystemKind::Harmonia, apps::corrective_rag(), rate, n, None, seed);
    let y = run_point(SystemKind::Haystack, apps::corrective_rag(), rate, n, None, seed);

    let comps = ["retriever", "grader", "rewriter", "websearch", "generator"];
    let mut t = Table::new(
        &format!("C-RAG at {rate} req/s: per-visit mean times (ms)"),
        &["component", "haystack svc", "haystack queue", "harmonia svc", "harmonia queue"],
    );
    for c in comps {
        let hs = h.report.components.get(c);
        let ys = y.report.components.get(c);
        t.row(&[
            c.to_string(),
            f(ys.map_or(0.0, |s| s.mean_service()) * 1e3, 1),
            f(ys.map_or(0.0, |s| s.mean_queue()) * 1e3, 1),
            f(hs.map_or(0.0, |s| s.mean_service()) * 1e3, 1),
            f(hs.map_or(0.0, |s| s.mean_queue()) * 1e3, 1),
        ]);
    }
    t.print();

    // The grader must be the costliest stage, and Harmonia must shrink
    // its queueing relative to the uniform-allocation baseline.
    let grader_q_h = h.report.components["grader"].mean_queue();
    let grader_q_y = y.report.components["grader"].mean_queue();
    let grader_svc = y.report.components["grader"].mean_service();
    let gen_svc = y.report.components["generator"].mean_service();
    println!(
        "\ngrader/generator service ratio: {} (paper: ~1.8x — grader is the bottleneck)",
        f(grader_svc / gen_svc, 2)
    );
    println!(
        "grader mean queue: haystack {} ms → harmonia {} ms",
        f(grader_q_y * 1e3, 1),
        f(grader_q_h * 1e3, 1)
    );
    println!("final harmonia instance counts: {:?}", {
        let mut v: Vec<_> = h.final_instances.iter().collect();
        v.sort();
        v
    });
    println!(
        "SHAPE CHECK: Harmonia alleviates the grader bottleneck: {}",
        if grader_q_h < grader_q_y { "REPRODUCED" } else { "NOT reproduced" }
    );
}
