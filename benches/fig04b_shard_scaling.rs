//! Figure 4b (extension) — Sharded retrieval scaling: batched
//! scatter-gather search throughput vs. shard count, against the
//! single-index baseline at the same total `search_ef` budget.
//!
//! The paper's claim this bench pins down: retrieval has *unique
//! scalability characteristics* — partitioning the corpus into S shards
//! searched in parallel cuts per-query service time toward 1/S (plus a
//! scatter/merge overhead) and raises batched throughput, without moving
//! the recall/`search_ef` trade-off (Fig. 4). The measured curve also
//! calibrates `sim::cluster::shard_service_factor`.

use std::time::Instant;

use harmonia::retrieval::{IvfIndex, IvfParams, ShardParams, ShardedIndex};
use harmonia::util::bench::smoke_scale;
use harmonia::util::table::{f, Table};
use harmonia::workload::{Corpus, QueryGen};

fn main() {
    // `--smoke`: shrink the corpus/probe budget so CI can execute the
    // bench end-to-end in seconds (see util::bench::smoke).
    let n = smoke_scale(40_000, 6_000);
    let dim = 64;
    let k = 10;
    let search_ef = smoke_scale(4096, 512);
    let batch = smoke_scale(64, 16);
    println!(
        "Figure 4b: sharded scatter-gather retrieval scaling \
         (corpus n={n}, d={dim}, K={k}, search_ef={search_ef}, batch={batch})\n"
    );

    let corpus = Corpus::generate(n, 64, 64, 0xF16_4B);
    let mut vectors = Vec::with_capacity(n * dim);
    for p in &corpus.passages {
        vectors.extend(Corpus::hash_embed(&p.text, dim));
    }

    let mut qg = QueryGen::new(&corpus, 7);
    let queries: Vec<Vec<f32>> =
        (0..batch).map(|_| Corpus::hash_embed(&qg.next().text, dim)).collect();

    // Baseline: one IVF index over the whole corpus, batched search.
    let ivf = IvfParams { n_lists: 256, kmeans_iters: 6, seed: 1, ..IvfParams::default() };
    let single = IvfIndex::build(vectors.clone(), dim, ivf);
    let exact: Vec<_> = queries.iter().map(|q| single.search_exact(q, k)).collect();

    let time_batched = |run: &dyn Fn() -> Vec<Vec<harmonia::retrieval::SearchResult>>| {
        // Warm up, then take the best of 3 passes (steadier on shared
        // machines than a single pass).
        let _ = run();
        let mut best = f64::INFINITY;
        let mut results = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            let r = run();
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
                results = r;
            }
        }
        (best, results)
    };

    let recall_of = |results: &[Vec<harmonia::retrieval::SearchResult>]| -> f64 {
        results
            .iter()
            .zip(&exact)
            .map(|(g, e)| IvfIndex::recall(g, e))
            .sum::<f64>()
            / results.len() as f64
    };

    let (t_single, r_single) = time_batched(&|| single.search_batch(&queries, k, search_ef));
    let qps_single = batch as f64 / t_single;

    let mut t = Table::new(
        "batched multi-shard search vs single index (equal total search_ef)",
        &["shards", "qps", "us/query", "recall@10", "speedup vs single"],
    );
    t.row(&[
        "1 (single)".into(),
        f(qps_single, 0),
        f(t_single / batch as f64 * 1e6, 1),
        f(recall_of(&r_single), 3),
        "1.0x".into(),
    ]);

    let mut qps_at_4 = 0.0;
    for shards in [2usize, 4, 8] {
        let idx = ShardedIndex::build(
            vectors.clone(),
            dim,
            ShardParams { n_shards: shards, ivf },
        );
        let (dt, results) = time_batched(&|| idx.search_batch(&queries, k, search_ef));
        let qps = batch as f64 / dt;
        if shards == 4 {
            qps_at_4 = qps;
        }
        t.row(&[
            shards.to_string(),
            f(qps, 0),
            f(dt / batch as f64 * 1e6, 1),
            f(recall_of(&results), 3),
            format!("{}x", f(qps / qps_single, 2)),
        ]);
    }
    t.print();

    println!(
        "\nSHAPE CHECK: batched 4-shard throughput exceeds the single-index \
         baseline: {}",
        if qps_at_4 > qps_single { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "(calibration target for sim::cluster::shard_service_factor — \
         factor(4) = {:.3})",
        harmonia::sim::cluster::shard_service_factor(4)
    );
}
