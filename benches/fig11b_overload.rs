//! Figure 11b (extension) — Overload control: goodput, shed rate, and
//! p99 / SLO violations vs offered load, across the policy ladder
//! FIFO → EDF → EDF+admission → EDF+admission+degradation.
//!
//! The claim this bench pins down: queue *reordering* (EDF) stops
//! helping once offered load exceeds capacity — every order loses when
//! the whole backlog is late. Admission-time shedding (negative
//! predicted slack + backpressure, Harmonia-style) and graduated
//! degradation (top-k shrink / hop skip / iteration caps, RAGO-style)
//! keep goodput near capacity and p99 near the SLO through 3× overload,
//! at the price of an explicit, *measured* shed rate — instead of an
//! implicit 100% violation rate.
//!
//! Accepts `--smoke` (see `util::bench::smoke`) for the CI quick pass.

use harmonia::sched::SchedConfig;
use harmonia::sim::{SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::util::bench::{smoke, smoke_scale};
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

/// Nominal V-RAG capacity on the paper testbed: the LP places ~9
/// RAM-bound retriever instances × 8 slots at ~0.1 s mean service
/// (≈730 req/s); the generator pool is not the bottleneck.
const CAPACITY: f64 = 730.0;
const SLO: f64 = 2.0;
const SEED: u64 = 0xF16_11B;

struct Policy {
    name: &'static str,
    edf: bool,
    sched: SchedConfig,
}

fn policies() -> Vec<Policy> {
    let admission_only = SchedConfig {
        admission: harmonia::sched::AdmissionConfig { enabled: true, ..Default::default() },
        ..SchedConfig::default()
    };
    vec![
        Policy { name: "fifo", edf: false, sched: SchedConfig::default() },
        Policy { name: "edf", edf: true, sched: SchedConfig::default() },
        Policy { name: "edf+admission", edf: true, sched: admission_only },
        Policy { name: "edf+adm+degrade", edf: true, sched: SchedConfig::overload_defense() },
    ]
}

fn main() {
    let n = smoke_scale(4000, 500);
    println!(
        "Figure 11b: overload control plane on v-rag (capacity ≈ {CAPACITY} req/s, \
         SLO = {SLO} s, n = {n}{})\n",
        if smoke() { ", --smoke" } else { "" }
    );

    let multipliers = [0.5, 1.0, 1.5, 2.0, 2.5, 3.0];
    // (policy, multiplier) -> (violation %, goodput) for the shape check.
    let mut viol = vec![vec![0.0f64; multipliers.len()]; 4];
    let mut good = vec![vec![0.0f64; multipliers.len()]; 4];

    for (mi, mult) in multipliers.iter().enumerate() {
        let rate = CAPACITY * mult;
        let mut t = Table::new(
            &format!("offered load {}x capacity ({} req/s)", f(*mult, 1), f(rate, 0)),
            &["policy", "goodput/s", "shed %", "p99 (s)", "SLO viol %", "degraded"],
        );
        for (pi, p) in policies().iter().enumerate() {
            let trace = TraceConfig { rate, n, slo: Some(SLO), ..TraceConfig::default() };
            let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
            cfg.ablation.slo_sched = p.edf;
            cfg.sched = p.sched;
            let r = SimWorld::simulate(apps::vanilla_rag(), cfg);
            let rep = &r.report;
            let shed_pct = 100.0 * rep.shed as f64 / n as f64;
            let degraded = rep.sched.map_or(0, |s| s.degraded);
            viol[pi][mi] = rep.slo_violation_rate * 100.0;
            good[pi][mi] = rep.goodput();
            t.row(&[
                p.name.to_string(),
                f(rep.goodput(), 1),
                f(shed_pct, 1),
                f(rep.p99, 3),
                f(rep.slo_violation_rate * 100.0, 1),
                format!("{degraded}"),
            ]);
        }
        t.print();
        println!();
    }

    // Shape check: at >= 2x offered load the full defense must cut p99
    // SLO violations vs plain EDF (the acceptance criterion), and hold
    // goodput at least as high.
    let overload_idx: Vec<usize> = multipliers
        .iter()
        .enumerate()
        .filter(|(_, m)| **m >= 2.0)
        .map(|(i, _)| i)
        .collect();
    let defense_cuts_violations = overload_idx.iter().all(|&i| viol[3][i] < viol[1][i]);
    let defense_holds_goodput = overload_idx.iter().all(|&i| good[3][i] >= good[1][i] * 0.9);
    println!(
        "SHAPE CHECK: EDF+admission+degrade reduces SLO violations vs EDF at >=2x load: {}",
        if defense_cuts_violations { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: defense holds goodput within 10% of EDF at >=2x load: {}",
        if defense_holds_goodput { "REPRODUCED" } else { "NOT reproduced" }
    );
}
