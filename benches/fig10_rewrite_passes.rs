//! Figure 10 (extension) — Spec-compiler rewrite passes: speculative
//! prefetch applied to the serial hybrid-RAG chain, vs the chain as
//! written, at equal allocation.
//!
//! The claim this bench pins down: the opt-in rewrite pipeline
//! (`spec::passes`, default OFF) finds latency that's free at the spec
//! level. `SpeculativePrefetch` rewrites the serial retrieve → websearch
//! chain of `hybrid-rag-seq` into a fork/join — both retrievals launch
//! the moment the source commits — so the modeled critical path drops
//! from retr + web to max(retr, web) while the allocation LP provisions
//! the *same* node set at the same resource bill. The DES then shows the
//! win surviving queueing: p50/p99 and TTFT p50/p99 all improve at equal
//! allocation, mechanically, with no hand-written parallel app.
//!
//! Runs under `GenBatching::Continuous` so TTFT is measured at decode
//! granularity. Accepts `--smoke` (see `util::bench::smoke`) for CI.

use harmonia::profile::{graph_latency, profile_graph, GenBatching};
use harmonia::sim::{SimConfig, SimWorld, SystemKind};
use harmonia::spec::{apps, Pass, PipelineGraph, SpeculativePrefetch, StageFusion};
use harmonia::util::bench::{smoke, smoke_scale};
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

const SLO: f64 = 2.0;
const SEED: u64 = 0xF16_10;

fn run(graph: PipelineGraph, rate: f64, n: usize) -> harmonia::sim::SimResult {
    let trace = TraceConfig { rate, n, slo: Some(SLO), ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.gen_batching = GenBatching::Continuous;
    SimWorld::simulate(graph, cfg)
}

fn main() {
    let n = smoke_scale(2000, 300);
    println!(
        "Figure 10: rewrite passes — speculative prefetch on the serial \
         hybrid chain (SLO = {SLO} s, n = {n}{})\n",
        if smoke() { ", --smoke" } else { "" }
    );

    let serial = apps::hybrid_rag_sequential();
    let prefetched = SpeculativePrefetch::default()
        .apply(&serial)
        .expect("hybrid-rag-seq contains a 2-stage retrieval chain");

    // Modeled critical paths from the deploy-time profile: what the
    // rewrite should save before any queueing (Σ branches → max branch).
    let ps = profile_graph(&serial, 2000, SEED);
    let pp = profile_graph(&prefetched, 2000, SEED);
    let (model_serial, model_prefetch) = (
        graph_latency(&serial, &ps.mean_service),
        graph_latency(&prefetched, &pp.mean_service),
    );
    println!(
        "modeled critical path: as-written {model_serial:.3} s vs +prefetch \
         {model_prefetch:.3} s ({:.0}% cut)",
        100.0 * (1.0 - model_prefetch / model_serial)
    );
    // Stage fusion is structural, not a latency play: it trades a
    // dispatch hop for a merged stage on mq-rag-seq.
    if let Some(fused) = StageFusion::default().apply(&apps::multiquery_rag_sequential(3)) {
        println!(
            "stage fusion [{}]: {} work nodes (from {})",
            fused.name,
            fused.work_nodes().count(),
            apps::multiquery_rag_sequential(3).work_nodes().count()
        );
    }
    println!();

    let rates = [16.0, 64.0];
    let mut p50_wins = true;
    let mut p99_wins = true;
    let mut ttft_wins = true;

    for &rate in &rates {
        let pre = run(prefetched.clone(), rate, n);
        let ser = run(serial.clone(), rate, n);
        let mut t = Table::new(
            &format!("hybrid chain @ {} req/s", f(rate, 0)),
            &["shape", "p50 (s)", "p99 (s)", "TTFT p50", "TTFT p99", "goodput/s"],
        );
        for (shape, r) in [("+prefetch", &pre), ("as-written", &ser)] {
            let g = r.report.gen.expect("continuous mode records TTFT");
            t.row(&[
                shape.to_string(),
                f(r.report.p50, 3),
                f(r.report.p99, 3),
                f(g.ttft_p50, 3),
                f(g.ttft_p99, 3),
                f(r.report.goodput(), 1),
            ]);
        }
        t.print();
        println!();
        let (gp, gs) = (pre.report.gen.unwrap(), ser.report.gen.unwrap());
        p50_wins &= pre.report.p50 < ser.report.p50;
        p99_wins &= pre.report.p99 < ser.report.p99;
        ttft_wins &= gp.ttft_p50 < gs.ttft_p50 && gp.ttft_p99 < gs.ttft_p99;
        if rate == rates[0] {
            print!("{}", pre.report.breakdown_table("+prefetch breakdown"));
            println!();
        }
    }

    println!(
        "SHAPE CHECK: modeled critical path strictly shrinks under prefetch: {}",
        if model_prefetch < model_serial { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: prefetch strictly cuts p50 at equal allocation at every rate: {}",
        if p50_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: prefetch strictly cuts p99 at equal allocation at every rate: {}",
        if p99_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
    println!(
        "SHAPE CHECK: prefetch strictly cuts p50+p99 TTFT vs the serial chain: {}",
        if ttft_wins { "REPRODUCED" } else { "NOT reproduced" }
    );
}
