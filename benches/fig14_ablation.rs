//! Figure 14 — Contribution of each runtime mechanism at 64 req/s:
//! disable each of {resource reallocation, load/state-aware routing,
//! communication-granularity management} in turn; the importance of a
//! mechanism is the throughput drop relative to full Harmonia,
//! normalized into proportional contributions.
//!
//! Paper: realloc dominates C-RAG/S-RAG/A-RAG (86.8%/78.5%/52.1%);
//! routing leads V-RAG (~44%) with streaming close (56.2% in V-RAG);
//! no single optimization suffices.

use harmonia::sim::{AblationFlags, SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::util::table::{f, Table};
use harmonia::workload::TraceConfig;

/// The paper runs this at 64 req/s ≈ 80% of its testbed capacity; our
/// calibrated substrate is ~5x faster, so we use the same *utilization*
/// (≈0.8 x each app's Harmonia plateau from Fig. 9).
fn rate_for(app: &str) -> f64 {
    match app {
        "v-rag" => 520.0,
        "c-rag" => 300.0,
        "s-rag" => 330.0,
        "a-rag" => 300.0,
        _ => 64.0,
    }
}

fn run(app: &str, flags: AblationFlags, seed: u64) -> f64 {
    let rate = rate_for(app);
    let trace = TraceConfig { rate, n: (rate * 60.0) as usize, slo: None, ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, seed);
    cfg.ablation = flags;
    // The reallocation mechanism is exercised under workload shift: the
    // deploy-time profile is biased (the paper's "offline estimates ...
    // deviate"), and the runtime corrects it from telemetry.
    cfg.profile_bias = 1.6;
    let r = SimWorld::simulate(apps::by_name(app).unwrap(), cfg);
    r.report.throughput
}

fn main() {
    println!("Figure 14 reproduction: per-mechanism contribution at ~80% utilization\n(paper: 64 req/s on its testbed; scaled to this substrate\u{2019}s capacity)\n");
    let seed = 0xF16_14;
    let mut t = Table::new(
        "proportional contribution to Harmonia's gain (%)",
        &["workflow", "realloc", "routing", "stream mgmt"],
    );
    let mut per_app = Vec::new();
    for app in ["v-rag", "c-rag", "s-rag", "a-rag"] {
        let full = run(app, AblationFlags::default(), seed);
        let no_realloc = run(app, AblationFlags { realloc: false, ..Default::default() }, seed);
        let no_routing = run(app, AblationFlags { routing: false, ..Default::default() }, seed);
        let no_stream = run(app, AblationFlags { stream_mgmt: false, ..Default::default() }, seed);
        let drops = [
            (full - no_realloc).max(0.0),
            (full - no_routing).max(0.0),
            (full - no_stream).max(0.0),
        ];
        let total: f64 = drops.iter().sum::<f64>().max(1e-9);
        let shares: Vec<f64> = drops.iter().map(|d| 100.0 * d / total).collect();
        t.row(&[
            app.to_string(),
            f(shares[0], 1),
            f(shares[1], 1),
            f(shares[2], 1),
        ]);
        per_app.push((app, shares));
    }
    t.print();

    println!("\npaper: realloc 86.8/78.5/52.1% for C/S/A-RAG; routing ~44% & streaming ~56% for V-RAG");
    let vrag = &per_app[0].1;
    let crag = &per_app[1].1;
    println!(
        "SHAPE CHECK: realloc dominates conditional pipelines while V-RAG is led by routing+streaming: {}",
        if crag[0] > crag[1] && crag[0] > crag[2] && (vrag[1] + vrag[2]) > vrag[0] {
            "REPRODUCED"
        } else {
            "NOT reproduced"
        }
    );
}
