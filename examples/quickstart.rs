//! Quickstart: deploy a Vanilla RAG pipeline live (real AOT-compiled XLA
//! artifacts, worker threads, central controller) and answer a few
//! queries.
//!
//!     make artifacts && cargo run --release --example quickstart

use harmonia::coordinator::controller::{deploy, ControllerConfig};
use harmonia::runtime::{artifacts_available, default_artifacts_dir};
use harmonia::spec::apps;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    println!("deploying v-rag (retriever → generator) with live XLA workers...");
    let mut cfg = ControllerConfig::quick(default_artifacts_dir());
    cfg.corpus_size = 512;
    cfg.n_topics = 8;
    let graph = apps::vanilla_rag();
    println!(
        "pipeline: {} (conditional: {}, recursive: {})",
        graph.name,
        graph.has_conditionals(),
        graph.has_recursion()
    );
    let h = deploy(graph, cfg)?;

    for q in [
        "what is the latest version of the linux kernel?",
        "where is hawaii?",
        "explain retrieval augmented generation",
    ] {
        let rx = h.submit(q.as_bytes());
        let r = rx.recv()?;
        println!(
            "\nQ: {q}\n  -> {} bytes generated in {:.3}s over {} stages (docs: {:?})",
            r.answer.len(),
            r.latency_secs,
            r.hops,
            r.error.as_deref().unwrap_or("ok"),
        );
        println!("  A (bytes): {:?}", String::from_utf8_lossy(&r.answer));
    }

    let report = h.report();
    println!("\n== run metrics ==");
    println!("completed: {}", report.completed);
    println!("mean latency: {:.3}s  p95: {:.3}s", report.mean_latency, report.p95);
    for (name, c) in &report.components {
        println!(
            "  {name:<12} execs={} mean service={:.1}ms mean queue={:.1}ms",
            c.executions,
            c.mean_service() * 1e3,
            c.mean_queue() * 1e3
        );
    }
    h.shutdown();
    Ok(())
}
