//! Paper-scale cluster simulation: run any of the four RAG workflows
//! under Harmonia / LangChain-like / Haystack-like serving on the
//! simulated 4×8-GPU testbed and print the run report.
//!
//!     cargo run --release --example cluster_sim -- [app] [system] [rate] [n]
//!     cargo run --release --example cluster_sim -- c-rag harmonia 48 2000

use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::apps;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let app = args.first().map(|s| s.as_str()).unwrap_or("c-rag");
    let system = match args.get(1).map(|s| s.as_str()).unwrap_or("harmonia") {
        "harmonia" => SystemKind::Harmonia,
        "langchain" => SystemKind::LangChain,
        "haystack" => SystemKind::Haystack,
        other => anyhow::bail!("unknown system '{other}' (harmonia|langchain|haystack)"),
    };
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(48.0);
    let n: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2000);

    let graph = apps::by_name(app)
        .ok_or_else(|| anyhow::anyhow!("unknown app '{app}' (v-rag|c-rag|s-rag|a-rag)"))?;
    println!(
        "simulating {} on {} at {rate} req/s ({n} requests, 4 nodes x 8 GPUs)...",
        graph.name,
        system.name()
    );
    let r = run_point(system, graph, rate, n, Some(2.0), 42);

    println!("\n== report ==");
    println!("completed:          {}", r.report.completed);
    println!("throughput:         {:.2} req/s", r.report.throughput);
    println!(
        "latency mean/p50/p95/p99: {:.3}/{:.3}/{:.3}/{:.3} s",
        r.report.mean_latency, r.report.p50, r.report.p95, r.report.p99
    );
    println!("SLO violations:     {:.1}%", r.report.slo_violation_rate * 100.0);
    println!("controller:         {} decisions, {:.1} us each", r.controller_decisions, r.controller_decision_secs * 1e6);
    println!("reallocations:      {} (LP solves: {})", r.reallocations, r.lp_solve_secs.len());
    let mut insts: Vec<_> = r.final_instances.iter().collect();
    insts.sort();
    println!("final instances:    {insts:?}");
    println!("\ncomponent breakdown:");
    let mut comps: Vec<_> = r.report.components.iter().collect();
    comps.sort_by(|a, b| a.0.cmp(b.0));
    for (name, c) in comps {
        println!(
            "  {name:<16} execs={:<6} service={:>7.1}ms queue={:>7.1}ms",
            c.executions,
            c.mean_service() * 1e3,
            c.mean_queue() * 1e3
        );
    }
    Ok(())
}
