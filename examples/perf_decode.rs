//! §Perf harness: measures the live data-plane hot paths —
//! batched decode steps/s (tokens/s), prefill/s, embedder throughput and
//! IVF search latency. Used for the EXPERIMENTS.md §Perf before/after log.
//!
//!     cargo run --release --example perf_decode

use std::time::Instant;

use harmonia::retrieval::{IvfIndex, IvfParams};
use harmonia::runtime::generator::{GenRequest, Generator};
use harmonia::runtime::{artifacts_available, default_artifacts_dir};
use harmonia::workload::Corpus;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("run `make artifacts` first");
    }
    let dir = default_artifacts_dir();

    // --- generator decode loop -------------------------------------------
    let g = Generator::new(&dir)?;
    for batch in [1usize, 4, 8] {
        let reqs: Vec<GenRequest> = (0..batch)
            .map(|i| GenRequest::greedy(format!("perf probe {i} quick brown fox").as_bytes(), 32))
            .collect();
        // warmup
        let _ = g.generate_batch(&reqs, |_, _| {})?;
        let t0 = Instant::now();
        let mut steps = 0usize;
        let mut toks = 0usize;
        let iters = 3;
        for _ in 0..iters {
            let (res, timing) = g.generate_batch(&reqs, |_, _| {})?;
            steps += timing.decode_steps;
            toks += res.iter().map(|r| r.generated_tokens).sum::<usize>();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "decode b{batch}: {:.1} steps/s, {:.1} tokens/s (steps {steps}, tokens {toks}, {dt:.2}s)",
            steps as f64 / dt,
            toks as f64 / dt
        );
    }

    // --- prefill ----------------------------------------------------------
    let reqs: Vec<GenRequest> =
        (0..8).map(|i| GenRequest::greedy(format!("prefill probe {i}").as_bytes(), 1)).collect();
    let t0 = Instant::now();
    let iters = 10;
    for _ in 0..iters {
        let _ = g.generate_batch(&reqs, |_, _| {})?;
    }
    println!(
        "prefill b8: {:.1} prefills/s",
        (iters * 8) as f64 / t0.elapsed().as_secs_f64()
    );

    // --- embedder ----------------------------------------------------------
    let e = harmonia::runtime::embedder::Embedder::new(&dir)?;
    let texts: Vec<Vec<u8>> = (0..64).map(|i| format!("embed probe {i}").into_bytes()).collect();
    let _ = e.embed_all(&texts)?;
    let t0 = Instant::now();
    let iters = 5;
    for _ in 0..iters {
        let _ = e.embed_all(&texts)?;
    }
    println!(
        "embedder: {:.1} texts/s",
        (iters * texts.len()) as f64 / t0.elapsed().as_secs_f64()
    );

    // --- IVF search ---------------------------------------------------------
    let dim = 64;
    let n = 40_000;
    let corpus = Corpus::generate(n, 64, 64, 0);
    let mut vectors = Vec::with_capacity(n * dim);
    for p in &corpus.passages {
        vectors.extend(Corpus::hash_embed(&p.text, dim));
    }
    let index = IvfIndex::build(
        vectors,
        dim,
        IvfParams { n_lists: 256, kmeans_iters: 6, seed: 1, ..IvfParams::default() },
    );
    let queries: Vec<Vec<f32>> =
        (0..256).map(|i| Corpus::hash_embed(format!("q{i}").as_bytes(), dim)).collect();
    let t0 = Instant::now();
    let mut hits = 0usize;
    for q in &queries {
        hits += index.search(q, 10, 2048).len();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "ivf search (ef=2048, k=10): {:.0} queries/s ({:.1} us/query, {hits} hits)",
        queries.len() as f64 / dt,
        dt / queries.len() as f64 * 1e6
    );
    Ok(())
}
