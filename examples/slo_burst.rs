//! SLO management under bursts: Adaptive-RAG served with and without
//! deadline-aware (EDF + predicted slack) scheduling. Execution
//! heterogeneity (LLM-only vs multi-step paths) creates slack the
//! scheduler exploits — the paper's §4.1 explanation for A-RAG's 78.4%
//! SLO-violation reduction.
//!
//!     cargo run --release --example slo_burst

use harmonia::sim::{AblationFlags, SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::workload::TraceConfig;

fn main() {
    println!("SLO burst study: a-rag at high load, EDF+slack vs FIFO\n");
    let slo = 2.5;
    for (label, slo_sched) in [("deadline-aware (harmonia)", true), ("fifo (ablated)", false)] {
        let trace = TraceConfig { rate: 56.0, n: 3000, slo: Some(slo), ..TraceConfig::default() };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 11);
        cfg.ablation = AblationFlags { slo_sched, ..Default::default() };
        let r = SimWorld::simulate(apps::adaptive_rag(), cfg);
        println!(
            "{label:<28} violations: {:>5.1}%   mean {:.3}s  p95 {:.3}s  p99 {:.3}s",
            r.report.slo_violation_rate * 100.0,
            r.report.mean_latency,
            r.report.p95,
            r.report.p99
        );
    }
    println!("\n(lower violations with identical resources = pure scheduling win)");
}
