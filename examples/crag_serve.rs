//! End-to-end validation driver (EXPERIMENTS.md §E2E): deploy Corrective
//! RAG live — real embedder, IVF index over a generated corpus, real
//! grader/rewriter/generator decode loops via PJRT — then serve a batch
//! of requests with Poisson arrivals and report latency/throughput and
//! the per-component breakdown.
//!
//!     make artifacts && cargo run --release --example crag_serve [n_requests]

use std::time::{Duration, Instant};

use harmonia::coordinator::controller::{deploy, ControllerConfig};
use harmonia::runtime::{artifacts_available, default_artifacts_dir};
use harmonia::spec::apps;
use harmonia::util::rng::Rng;
use harmonia::workload::{Corpus, QueryGen};

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let rate = 4.0; // offered load, req/s

    println!("== E2E driver: C-RAG live serving ==");
    println!("requests: {n_requests}, Poisson rate: {rate}/s");
    let mut cfg = ControllerConfig::quick(default_artifacts_dir());
    cfg.corpus_size = 512;
    cfg.n_topics = 8;
    cfg.slo = Some(8.0);
    let t0 = Instant::now();
    let h = deploy(apps::corrective_rag(), cfg)?;
    // Warm up: workers compile their PJRT engines lazily at start (the
    // paper's stateful-actor cold start, §3.1); a probe request through
    // both branches makes the measured run reflect steady state.
    for probe in ["warmup probe one", "warmup probe two", "warmup probe three"] {
        let _ = h.submit(probe.as_bytes()).recv_timeout(Duration::from_secs(300))?;
    }
    println!(
        "deployed + warmed in {:.1}s (engine compilation is the cold start)",
        t0.elapsed().as_secs_f64()
    );

    // Query stream resembling the corpus topics.
    let corpus = Corpus::generate(512, 8, 64, 0);
    let mut qg = QueryGen::new(&corpus, 99);
    let mut rng = Rng::new(7);

    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let q = qg.next();
        pending.push((i, h.submit(&q.text)));
        // Poisson arrivals.
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
    }
    let mut latencies = Vec::new();
    let mut web_hops = 0usize;
    for (i, rx) in pending {
        let r = rx.recv_timeout(Duration::from_secs(600))?;
        if let Some(e) = &r.error {
            anyhow::bail!("request {i} failed: {e}");
        }
        if r.hops == 5 {
            web_hops += 1;
        }
        latencies.push(r.latency_secs);
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = latencies.iter().sum::<f64>() / latencies.len() as f64;
    let p = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    println!("\n== results ==");
    println!("completed:      {n_requests}/{n_requests}");
    println!("wall time:      {wall:.1}s → throughput {:.2} req/s", n_requests as f64 / wall);
    println!("latency mean:   {mean:.3}s  p50: {:.3}s  p95: {:.3}s", p(0.5), p(0.95));
    println!(
        "control flow:   {}/{} requests took the low-relevance path (rewrite → web search)",
        web_hops, n_requests
    );

    let report = h.report();
    println!("\nper-component breakdown:");
    let mut comps: Vec<_> = report.components.iter().collect();
    comps.sort_by(|a, b| a.0.cmp(b.0));
    for (name, c) in comps {
        println!(
            "  {name:<12} execs={:<4} mean service={:>7.1}ms  mean queue={:>7.1}ms",
            c.executions,
            c.mean_service() * 1e3,
            c.mean_queue() * 1e3
        );
    }
    println!("\nSLO (8s) violation rate: {:.1}%", report.slo_violation_rate * 100.0);
    h.shutdown();
    Ok(())
}
