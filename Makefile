# Harmonia (Patchwork reproduction) — build / verify / bench entrypoints.
#
# `make verify` is the tier-1 gate plus lint: release build, tests,
# rustfmt check, and clippy with warnings denied.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test test-fast lint fmt clippy doc verify artifacts bench bench-shards bench-cache bench-overload bench-batching bench-parallel bench-disagg bench-perf bench-perf-smoke bench-retrieval bench-retrieval-smoke bench-live bench-live-smoke bench-live-alloc bench-smoke bench-passes graph-dot clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Unit + doc-free fast path: library tests only. Skips the bench
# binaries and the integration targets (`live_serving` needs XLA
# artifacts, `golden_trace` rides with the full `test`).
test-fast:
	$(CARGO) test --lib -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

lint: fmt clippy

# Rustdoc with warnings denied: keeps intra-doc links (EdgeKind/JoinSpec
# and friends) valid as the API evolves.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

verify: build test lint doc

# AOT-compile the JAX/Pallas models to XLA artifacts (live mode).
artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../artifacts

# Run every paper-figure bench (plain binaries; no harness).
bench:
	$(CARGO) bench

# The sharded-retrieval scaling bench only.
bench-shards:
	$(CARGO) bench --bench fig04b_shard_scaling

# The request-cache hit-curve bench only.
bench-cache:
	$(CARGO) bench --bench fig04c_cache_hit_curve

# The overload control-plane bench only (fig11b).
bench-overload:
	$(CARGO) bench --bench fig11b_overload

# The continuous-batching bench only (fig06).
bench-batching:
	$(CARGO) bench --bench fig06_continuous_batching

# The parallel-dataflow (fork/join) bench only (fig07).
bench-parallel:
	$(CARGO) bench --bench fig07_parallel_dataflow

# The prefill/decode disaggregation × KV prefix-cache bench only (fig08).
bench-disagg:
	$(CARGO) bench --bench fig08_disaggregation

# The spec-compiler rewrite-pass bench only (fig10 extension):
# speculative prefetch vs the serial hybrid chain at equal allocation.
bench-passes:
	$(CARGO) bench --bench fig10_rewrite_passes

# DES core perf: 10M simulated requests through the calendar-queue event
# loop; writes BENCH_des.json and gates against benches/baselines/.
bench-perf:
	$(CARGO) bench --bench perf_des

# CI variant: ~40k requests, same code paths and artifact shape.
bench-perf-smoke:
	$(CARGO) bench --bench perf_des -- --smoke

# Retrieval data-plane perf: blocked f32 vs SQ8 scan kernels + bounded-
# heap top-k; writes BENCH_retrieval.json and gates against
# benches/baselines/.
bench-retrieval:
	$(CARGO) bench --bench perf_retrieval

# CI variant: 20k-row corpus, same code paths and artifact shape.
bench-retrieval-smoke:
	$(CARGO) bench --bench perf_retrieval -- --smoke

# Live serving-path perf: closed-loop echo-engine deployments of
# v-rag-cached and hybrid-rag (real workers/index/router, deterministic
# stages); writes BENCH_live.json and gates against benches/baselines/.
bench-live:
	$(CARGO) bench --bench perf_live

# CI variant: smaller corpus and request count, same code paths and
# artifact shape.
bench-live-smoke:
	$(CARGO) bench --bench perf_live -- --smoke

# Allocation-counting variant: adds allocs-per-dispatch to the artifact.
# Throughput from this build is NOT comparable with the stock bench.
bench-live-alloc:
	$(CARGO) bench --bench perf_live --features count-alloc -- --smoke

# Quick-iteration bench pass (CI): actually *execute* the bench binaries
# with `--smoke`-shrunk workloads (see util::bench::smoke) instead of
# only compiling them. Keeps the paper-figure harnesses from bit-rotting.
bench-smoke:
	$(CARGO) bench --bench fig11b_overload -- --smoke
	$(CARGO) bench --bench fig04b_shard_scaling -- --smoke
	$(CARGO) bench --bench fig04c_cache_hit_curve -- --smoke
	$(CARGO) bench --bench fig06_continuous_batching -- --smoke
	$(CARGO) bench --bench fig07_parallel_dataflow -- --smoke
	$(CARGO) bench --bench fig08_disaggregation -- --smoke
	$(CARGO) bench --bench fig10_rewrite_passes -- --smoke

# Render every registered app spec to Graphviz DOT under target/dot/,
# with LP instance counts and modeled per-stage latencies overlaid.
graph-dot:
	$(CARGO) run --release -- dot target/dot

clean:
	$(CARGO) clean
