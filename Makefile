# Harmonia (Patchwork reproduction) — build / verify / bench entrypoints.
#
# `make verify` is the tier-1 gate plus lint: release build, tests,
# rustfmt check, and clippy with warnings denied.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test lint fmt clippy verify artifacts bench bench-shards clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

lint: fmt clippy

verify: build test lint

# AOT-compile the JAX/Pallas models to XLA artifacts (live mode).
artifacts:
	cd python/compile && $(PYTHON) aot.py --out ../../artifacts

# Run every paper-figure bench (plain binaries; no harness).
bench:
	$(CARGO) bench

# The sharded-retrieval scaling bench only.
bench-shards:
	$(CARGO) bench --bench fig04b_shard_scaling

clean:
	$(CARGO) clean
