//! Artifact-free live-path regression tests: the echo engine
//! (`ControllerConfig::echo`) deploys real pipelines onto real workers —
//! genuine retrieval index, fork/join barriers, router, slab — with
//! deterministic pure-function stages, so the controller's semantics are
//! pinned **bit-exactly** without XLA artifacts. These run in every CI
//! job (no artifact gate), which is the point: the zero-copy `RagState`
//! and dense-table controller refactors must never change a served byte.

use std::path::PathBuf;

use harmonia::coordinator::controller::{deploy, ControllerConfig};
use harmonia::exec::components::{build_live_shared, echo_answer};
use harmonia::exec::EngineMode;
use harmonia::spec::apps;
use harmonia::spec::{ComponentKind, JoinSpec, PipelineBuilder, ResourceKind};

const SEED: u64 = 7;

fn echo_cfg() -> ControllerConfig {
    let mut c = ControllerConfig::echo(SEED);
    // Small corpus keeps index build fast; no request cache so the
    // oracle below predicts every request (not just cold misses).
    c.corpus_size = 128;
    c.n_topics = 4;
    c.n_shards = 2;
    c.cache = None;
    c
}

/// The deployment's retrieval/context/answer parameters, reproduced
/// outside the serving stack. Everything flows from `build_live_shared`
/// with the same knobs the controller uses.
struct Oracle {
    shared: harmonia::exec::components::LiveShared,
}

impl Oracle {
    fn new(cfg: &ControllerConfig) -> Oracle {
        let shared = build_live_shared(
            PathBuf::new(),
            cfg.corpus_size,
            cfg.n_topics,
            cfg.n_shards,
            None,
            None,
            cfg.quantization,
            cfg.seed,
            EngineMode::Echo,
        )
        .expect("oracle shared state");
        Oracle { shared }
    }

    /// Context bytes the echo retriever produces for `query`
    /// (hash-embed → scatter-gather top-k → `fill_from_hits` layout).
    fn retrieved_context(&self, query: &[u8]) -> Vec<u8> {
        // 64 = the echo engine's embedding dim (ECHO_EMBED_DIM).
        let emb = harmonia::workload::Corpus::hash_embed(query, 64);
        let hits = self
            .shared
            .index
            .search_batch(&[emb], self.shared.k_docs, self.shared.search_ef)
            .remove(0);
        let mut ctx = Vec::new();
        for h in &hits {
            let p = &self.shared.corpus.passages[h.id];
            let take = p.text.len().min(self.shared.ctx_bytes_per_doc);
            ctx.extend_from_slice(&p.text[..take]);
            ctx.push(b' ');
        }
        ctx
    }

    /// Context bytes the echo web-search stage produces for `query`
    /// (deterministic passages keyed by query byte-sum).
    fn web_context(&self, query: &[u8]) -> Vec<u8> {
        let h: usize = query.iter().map(|&b| b as usize).sum();
        let n = self.shared.corpus.len();
        let mut ctx = Vec::new();
        for j in 0..self.shared.k_docs {
            let p = &self.shared.corpus.passages[(h + j * 7919) % n];
            let take = p.text.len().min(self.shared.ctx_bytes_per_doc);
            ctx.extend_from_slice(&p.text[..take]);
            ctx.push(b' ');
        }
        ctx
    }
}

#[test]
fn vanilla_echo_answers_match_oracle() {
    let cfg = echo_cfg();
    let oracle = Oracle::new(&cfg);
    let h = deploy(apps::vanilla_rag(), cfg).expect("deploy echo v-rag");

    let n = 24;
    for i in 0..n {
        let q = format!("echo oracle query {i} topic {}", i % 5);
        let r = h.submit(q.as_bytes()).recv().expect("response");
        assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        assert_eq!(r.hops, 2, "v-rag is retrieve → generate");
        let expected = echo_answer(&oracle.retrieved_context(q.as_bytes()), q.as_bytes());
        assert_eq!(
            r.answer,
            expected,
            "request {i}: served answer diverged from the out-of-stack oracle"
        );
    }

    let rep = h.report();
    assert_eq!(rep.completed, n as u64);
    assert_eq!(rep.shed, 0);
    let ctrl = rep.ctrl.expect("live run reports controller stats");
    assert_eq!(ctrl.dispatches, 2 * n as u64, "one dispatch per hop, no forks");
    assert_eq!(ctrl.completions, 2 * n as u64);
    assert!(ctrl.dispatch_secs > 0.0, "timed dispatch path");
    assert!(
        ctrl.busy_secs > 0.0 && ctrl.idle_secs >= 0.0,
        "busy/idle split populated: {ctrl:?}"
    );
    h.shutdown();
}

#[test]
fn hybrid_echo_union_merges_both_contexts() {
    let cfg = echo_cfg();
    let oracle = Oracle::new(&cfg);
    let h = deploy(apps::hybrid_rag(), cfg).expect("deploy echo hybrid-rag");

    let n = 12;
    for i in 0..n {
        let q = format!("hybrid echo query {i}");
        let r = h.submit(q.as_bytes()).recv().expect("response");
        assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        assert_eq!(r.hops, 3, "hybrid-rag is (retriever ∥ websearch) → generator");
        // The Union merge appends branch contexts in ARRIVAL order and
        // both orders are legal (the branches genuinely race), so the
        // served answer must equal one of the two possible digests.
        let retr = oracle.retrieved_context(q.as_bytes());
        let web = oracle.web_context(q.as_bytes());
        let mut retr_first = retr.clone();
        retr_first.extend_from_slice(&web);
        let mut web_first = web.clone();
        web_first.extend_from_slice(&retr);
        let a = echo_answer(&retr_first, q.as_bytes());
        let b = echo_answer(&web_first, q.as_bytes());
        assert!(
            r.answer == a || r.answer == b,
            "request {i}: answer {:?} is neither merge order's digest",
            String::from_utf8_lossy(&r.answer)
        );
    }

    let rep = h.report();
    assert_eq!(rep.completed, n as u64);
    let gen = rep.components.get("generator").expect("generator stats");
    assert_eq!(gen.joins, n as u64, "every request crossed the barrier once");
    let ctrl = rep.ctrl.expect("ctrl stats");
    // retriever + websearch + generator per request, every one dispatched.
    assert_eq!(ctrl.dispatches, 3 * n as u64);
    h.shutdown();
}

/// FirstK(1) race between the retriever and web search: the barrier
/// releases on the first arrival and the loser's completion must retire
/// harmlessly — including across slab slot recycling, where the loser's
/// `Done` carries a retired generation-tagged key.
#[test]
fn first_k_race_drops_loser_and_recycles_slots() {
    let mut b = PipelineBuilder::new("first-k-race");
    let res = [(ResourceKind::Cpu, 1.0)];
    let retr = b.component("retriever", ComponentKind::Retriever).resources(&res).add();
    let web = b.component("websearch", ComponentKind::WebSearch).resources(&res).add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&res)
        .join(JoinSpec::first_k(1))
        .add();
    b.fork(b.source(), &[retr, web]);
    b.edge(retr, gen, 1.0);
    b.edge(web, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    let g = b.build().expect("race graph is valid");

    let cfg = echo_cfg();
    let oracle = Oracle::new(&cfg);
    let h = deploy(g, cfg).expect("deploy race graph");

    // Sequential requests: each one recycles the single slab slot, so a
    // straggling loser from request i carries a stale key while request
    // i+1 owns the slot. Correctness = every request still completes
    // with a winner's digest.
    let n = 16;
    for i in 0..n {
        let q = format!("race query {i}");
        let r = h.submit(q.as_bytes()).recv().expect("response");
        assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        let winner_retr =
            echo_answer(&oracle.retrieved_context(q.as_bytes()), q.as_bytes());
        let winner_web = echo_answer(&oracle.web_context(q.as_bytes()), q.as_bytes());
        assert!(
            r.answer == winner_retr || r.answer == winner_web,
            "request {i}: answer {:?} is neither branch's digest",
            String::from_utf8_lossy(&r.answer)
        );
        // Winner + generator always complete before the response; the
        // loser may or may not have retired yet.
        assert!(
            (2..=3).contains(&r.hops),
            "request {i}: {} hops outside the race envelope",
            r.hops
        );
    }

    let rep = h.report();
    assert_eq!(rep.completed, n as u64, "losers never block completion");
    let gen_stats = rep.components.get("generator").expect("generator stats");
    assert_eq!(gen_stats.joins, n as u64, "exactly one barrier release per request");
    assert_eq!(gen_stats.executions, n as u64, "the generator runs once per request");
    h.shutdown();
}

/// Two identical deployments serve identical sequential workloads with
/// bit-identical answers and counters — the determinism contract the
/// perf bench's regression gate relies on.
#[test]
fn echo_runs_are_deterministic_across_deployments() {
    let serve = || {
        let h = deploy(apps::vanilla_rag(), echo_cfg()).expect("deploy");
        let mut answers = Vec::new();
        for i in 0..10 {
            let q = format!("determinism probe {i}");
            let r = h.submit(q.as_bytes()).recv().expect("response");
            assert!(r.error.is_none());
            answers.push(r.answer);
        }
        let rep = h.report();
        h.shutdown();
        (answers, rep.completed, rep.ctrl.map(|c| (c.dispatches, c.completions)))
    };
    let (a1, c1, ctrl1) = serve();
    let (a2, c2, ctrl2) = serve();
    assert_eq!(a1, a2, "served bytes must not depend on the deployment instance");
    assert_eq!(c1, c2);
    assert_eq!(ctrl1, ctrl2, "dispatch/completion counts are workload-determined");
}
