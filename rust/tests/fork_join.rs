//! Parallel-dataflow (fork/join) integration regression: the fig07
//! acceptance shape pinned at fixed seeds — hybrid retrieval and
//! multi-query expansion run end-to-end in the DES, strictly beat their
//! serialized equivalents on p50 AND p99 at equal allocation, stay
//! bit-reproducible, and leak nothing (router bindings at zero on every
//! terminal path). Always runs — no artifacts needed.

use harmonia::sim::{run_point, SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::workload::TraceConfig;

const SEED: u64 = 0x0F07;

#[test]
fn parallel_beats_serialized_on_p50_and_p99_at_equal_allocation() {
    // The acceptance criterion, deterministically: same trace, same
    // seed, same nodes/resources — overlap strictly wins both tails.
    for (name, par, seq) in [
        ("hybrid", apps::hybrid_rag(), apps::hybrid_rag_sequential()),
        ("mq", apps::multiquery_rag(3), apps::multiquery_rag_sequential(3)),
    ] {
        let p = run_point(SystemKind::Harmonia, par, 16.0, 400, Some(2.0), SEED);
        let s = run_point(SystemKind::Harmonia, seq, 16.0, 400, Some(2.0), SEED);
        assert_eq!(p.report.completed, 400, "{name}");
        assert_eq!(s.report.completed, 400, "{name}");
        assert!(
            p.report.p50 < s.report.p50,
            "{name}: parallel p50 {} must beat serialized {}",
            p.report.p50,
            s.report.p50
        );
        assert!(
            p.report.p99 < s.report.p99,
            "{name}: parallel p99 {} must beat serialized {}",
            p.report.p99,
            s.report.p99
        );
        assert_eq!(p.residual_bindings, 0, "{name}: bindings leaked");
    }
}

#[test]
fn fork_runs_are_bit_reproducible() {
    for app in ["hybrid-rag", "mq-rag"] {
        let g = apps::by_name(app).unwrap();
        let trace = TraceConfig { rate: 16.0, n: 250, slo: Some(2.0), ..TraceConfig::default() };
        let cfg_a = SimConfig::new(SystemKind::Harmonia, trace.clone(), SEED);
        let cfg_b = SimConfig::new(SystemKind::Harmonia, trace, SEED);
        let a = SimWorld::simulate(g.clone(), cfg_a);
        let b = SimWorld::simulate(g, cfg_b);
        assert_eq!(a.report.completed, b.report.completed, "{app}");
        assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits(), "{app}");
        assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits(), "{app}");
    }
}

#[test]
fn join_stall_is_reported_not_hidden() {
    // All-join: whichever branch lands first waits for its sibling; the
    // breakdown must surface that stall at the join node and render it.
    let r = run_point(SystemKind::Harmonia, apps::hybrid_rag(), 16.0, 300, Some(2.0), SEED);
    let gen = &r.report.components["generator"];
    assert_eq!(gen.joins, 300, "one barrier release per request");
    assert!(gen.mean_join_wait() > 0.0, "sibling stall must be visible");
    let table = r.report.breakdown_table("hybrid breakdown");
    assert!(table.contains("join-wait ms"), "{table}");
    assert!(table.contains("websearch"), "{table}");
}

#[test]
fn legacy_apps_carry_zero_fork_edges_and_identical_goldens() {
    // Pre-existing apps must be untouched by the fork/join refactor:
    // no Fork edges, no JoinSpec, no join stats in their reports — and
    // the fixed-seed V-RAG run still inside its golden band (the strict
    // band checks live in golden_trace.rs; this is the fork-specific
    // guard).
    for name in ["v-rag", "c-rag", "s-rag", "a-rag", "v-rag-sharded", "v-rag-cached"] {
        let g = apps::by_name(name).unwrap();
        assert!(!g.has_forks(), "{name}");
        assert!(g.nodes.iter().all(|n| n.join.is_none()), "{name}");
        assert!(g.fork_groups().is_empty(), "{name}");
    }
    let r = run_point(SystemKind::Harmonia, apps::vanilla_rag(), 8.0, 200, Some(2.0), 0x601D);
    assert_eq!(r.report.completed, 200);
    assert!(r.report.components.values().all(|c| c.joins == 0 && c.join_wait == 0.0));
}
