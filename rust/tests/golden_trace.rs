//! Golden-trace DES regression: a fixed-seed V-RAG simulation pins the
//! run-level summary statistics within tolerance bands, guarding future
//! scheduler / allocator / simulator refactors against silent behavior
//! drift. Always runs (no artifacts needed — the DES is pure Rust).
//!
//! The bands are derived from the calibrated latency models (see
//! `profile::models`): at 8 req/s V-RAG is lightly loaded, so end-to-end
//! latency ≈ retriever (~0.1 s mean) + generator (~0.1 s mean) plus
//! small queueing/controller overheads, and throughput tracks the
//! arrival rate. If an intentional model change moves a statistic out of
//! its band, re-pin the band in the same commit and say why.

use harmonia::sim::{run_point, SimConfig, SimWorld, SystemKind};
use harmonia::spec::apps;
use harmonia::workload::TraceConfig;

const SEED: u64 = 0x601D;
const RATE: f64 = 8.0;
const N: usize = 400;
const SLO: f64 = 2.0;

fn golden_run() -> harmonia::sim::SimResult {
    run_point(SystemKind::Harmonia, apps::vanilla_rag(), RATE, N, Some(SLO), SEED)
}

#[test]
fn golden_vrag_summary_stats_within_bands() {
    let r = golden_run();
    let rep = &r.report;
    // Every admitted request completes.
    assert_eq!(rep.completed, N as u64);
    // Throughput tracks the Poisson arrival rate over the active horizon
    // (light load: the system drains as fast as requests arrive).
    assert!(
        (6.0..10.0).contains(&rep.throughput),
        "throughput {} outside golden band [6, 10)",
        rep.throughput
    );
    // Latency bands from the calibrated models (retriever ≈ generator ≈
    // 0.1 s mean service at k_docs ∈ [100, 300]).
    assert!(
        (0.1..0.8).contains(&rep.mean_latency),
        "mean latency {} outside golden band [0.1, 0.8)",
        rep.mean_latency
    );
    assert!(
        (0.1..0.7).contains(&rep.p50),
        "p50 {} outside golden band [0.1, 0.7)",
        rep.p50
    );
    assert!(
        rep.p50 <= rep.p95 && rep.p95 <= rep.p99,
        "percentiles out of order: {} / {} / {}",
        rep.p50,
        rep.p95,
        rep.p99
    );
    assert!(
        rep.p99 < SLO,
        "p99 {} must clear the 2 s SLO at light load",
        rep.p99
    );
    // Light load, 2 s SLO: violations are rare events.
    assert!(
        rep.slo_violation_rate < 0.05,
        "violation rate {} outside golden band",
        rep.slo_violation_rate
    );
    // Both stages recorded, with V-RAG's "naturally balanced" ratio.
    let retr = rep.components["retriever"].mean_service();
    let genr = rep.components["generator"].mean_service();
    assert!(
        (0.5..2.0).contains(&(retr / genr)),
        "V-RAG balance drifted: retriever {retr} vs generator {genr}"
    );
    // No cache in the golden pipeline: the report must not grow one.
    assert!(rep.cache.is_none());
    // The overload control plane defaults off: nothing shed, no sched
    // section — the golden workload is untouched by the sched refactor.
    assert_eq!(rep.shed, 0);
    assert!(rep.sched.is_none());
    // The generator defaults collocated: no disaggregation section and
    // no KV prefix counters — the golden trace predates the split and
    // must stay byte-for-byte oblivious to it.
    assert!(rep.disagg.is_none());
    assert!(rep.kv_prefix.is_none());
}

#[test]
fn golden_run_identical_under_explicitly_default_sched_config() {
    // The sched knobs must be *inert* at their defaults, not merely
    // "mostly off": constructing the config by hand and via Default must
    // produce bit-identical runs (guards against a future knob that
    // defaults hot).
    let a = golden_run();
    let trace = TraceConfig { rate: RATE, n: N, slo: Some(SLO), ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.sched = harmonia::sched::SchedConfig {
        admission: harmonia::sched::AdmissionConfig::default(),
        degrade: harmonia::sched::DegradeConfig::default(),
        rekey_on_tick: false,
    };
    let b = SimWorld::simulate(apps::vanilla_rag(), cfg);
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
    assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
    assert_eq!(a.report.throughput.to_bits(), b.report.throughput.to_bits());
}

#[test]
fn golden_run_identical_under_explicitly_legacy_gen_batching() {
    // The continuous-batching knob must be *inert* at its default:
    // setting it to Legacy by hand must be bit-identical to the default
    // run, and the legacy model must record no TTFT/per-token section.
    let a = golden_run();
    let trace = TraceConfig { rate: RATE, n: N, slo: Some(SLO), ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.gen_batching = harmonia::profile::GenBatching::Legacy;
    let b = SimWorld::simulate(apps::vanilla_rag(), cfg);
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
    assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
    assert_eq!(a.report.throughput.to_bits(), b.report.throughput.to_bits());
    assert!(a.report.gen.is_none(), "legacy batching records no gen section");
    assert!(b.report.gen.is_none());
}

#[test]
fn golden_run_identical_under_explicitly_collocated_placement() {
    // The disaggregation knobs must be *inert* at their defaults: setting
    // `gen_placement: Collocated` (with the transfer model and prefix-hit
    // rate spelled out) by hand must replay the default run bit-identically
    // — same event order, same rng draws, same floats — and must emit no
    // disaggregation metrics section.
    let a = golden_run();
    let trace = TraceConfig { rate: RATE, n: N, slo: Some(SLO), ..TraceConfig::default() };
    let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, SEED);
    cfg.gen_placement = harmonia::profile::GenPlacement::Collocated;
    cfg.kv_transfer = harmonia::profile::models::KvTransferModel::default();
    cfg.kv_prefix_hit_rate = 0.0;
    let b = SimWorld::simulate(apps::vanilla_rag(), cfg);
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
    assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
    assert_eq!(a.report.throughput.to_bits(), b.report.throughput.to_bits());
    assert!(a.report.disagg.is_none(), "collocated default emits no disagg section");
    assert!(b.report.disagg.is_none(), "explicit Collocated emits no disagg section");
}

#[test]
fn golden_vrag_is_bit_reproducible() {
    // The golden statistics are only a regression anchor if the run is
    // exactly reproducible: identical seeds must give identical floats,
    // not merely close ones.
    let a = golden_run();
    let b = golden_run();
    assert_eq!(a.report.completed, b.report.completed);
    assert_eq!(a.report.throughput.to_bits(), b.report.throughput.to_bits());
    assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
    assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
    assert_eq!(
        a.report.slo_violation_rate.to_bits(),
        b.report.slo_violation_rate.to_bits()
    );
    // The calendar-queue event list must replay the exact same event
    // schedule: same event count, same clock, and zero past-time clamps
    // (a nonzero `clamped` would mean a model scheduled into the past —
    // the silent-reorder hazard `EventQueue::clamped` exists to expose).
    assert_eq!(a.events, b.events, "event count must be deterministic");
    assert!(a.events >= a.report.completed, "each request takes >=1 event");
    assert_eq!(a.clamped, 0, "golden models never schedule into the past");
    assert_eq!(b.clamped, 0);
}

#[test]
fn golden_bands_hold_across_all_reference_apps() {
    // Coarser guard for the conditional/recursive apps: everything
    // completes, percentiles are ordered, and the run stays deterministic.
    for app in ["c-rag", "s-rag", "a-rag"] {
        let g = apps::by_name(app).unwrap();
        let trace = TraceConfig { rate: 8.0, n: 200, slo: Some(4.0), ..TraceConfig::default() };
        let cfg = SimConfig::new(SystemKind::Harmonia, trace.clone(), SEED);
        let r = SimWorld::simulate(g.clone(), cfg);
        assert_eq!(r.report.completed, 200, "{app}");
        assert!(r.report.p50 <= r.report.p99, "{app}");
        assert!(r.report.slo_violation_rate < 0.5, "{app}: {}", r.report.slo_violation_rate);
        let r2 = SimWorld::simulate(g, SimConfig::new(SystemKind::Harmonia, trace, SEED));
        assert_eq!(
            r.report.mean_latency.to_bits(),
            r2.report.mean_latency.to_bits(),
            "{app} must be bit-reproducible"
        );
    }
}
