//! Integration tests: full live serving through real XLA artifacts —
//! deploy a pipeline, push requests, verify answers + control flow.
//! Skipped (with a notice) when `make artifacts` hasn't run.

use std::collections::HashMap;

use harmonia::coordinator::controller::{deploy, ControllerConfig};
use harmonia::runtime::{artifacts_available, default_artifacts_dir};
use harmonia::spec::apps;

fn cfg() -> ControllerConfig {
    let mut c = ControllerConfig::quick(default_artifacts_dir());
    c.corpus_size = 128; // keep index build fast
    c.n_topics = 4;
    c
}

#[test]
fn vanilla_rag_serves_end_to_end() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let h = deploy(apps::vanilla_rag(), cfg()).unwrap();
    let rx = h.submit(b"what is in topic zero?");
    let resp = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(resp.latency_secs > 0.0);
    assert_eq!(resp.hops, 2, "retriever + generator");
    let report = h.report();
    assert_eq!(report.completed, 1);
    assert!(report.components.contains_key("retriever"));
    assert!(report.components.contains_key("generator"));
    // No KV prefix cache configured → no counters section (the stock
    // deployment stays byte-for-byte the pre-disaggregation path).
    assert!(report.kv_prefix.is_none());
    h.shutdown();
}

#[test]
fn vanilla_rag_batched_requests() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let h = deploy(apps::vanilla_rag(), cfg()).unwrap();
    let rxs: Vec<_> = (0..6)
        .map(|i| h.submit(format!("query number {i} about something").as_bytes()))
        .collect();
    let mut answers = Vec::new();
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(180)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        answers.push(r.answer);
    }
    assert_eq!(answers.len(), 6);
    let report = h.report();
    assert_eq!(report.completed, 6);
    assert!(report.throughput > 0.0);
    h.shutdown();
}

#[test]
fn repeat_query_hits_the_request_cache() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // ControllerConfig::quick enables the cache by default; an exact
    // repeat must be served from the exact tier and produce the same
    // answer (same memoized context + greedy decoding).
    let h = deploy(apps::vanilla_rag(), cfg()).unwrap();
    let q: &[u8] = b"tell me about topic one";
    let first = h
        .submit(q)
        .recv_timeout(std::time::Duration::from_secs(120))
        .unwrap();
    assert!(first.error.is_none(), "{:?}", first.error);
    let second = h
        .submit(q)
        .recv_timeout(std::time::Duration::from_secs(120))
        .unwrap();
    assert!(second.error.is_none(), "{:?}", second.error);
    assert_eq!(first.answer, second.answer, "memoized retrieval must not change the answer");
    let report = h.report();
    let snap = report.cache.expect("cache counters in the live report");
    assert!(snap.exact_hits >= 1, "repeat did not hit: {snap:?}");
    assert!(snap.insertions >= 1);
    h.shutdown();
}

#[test]
fn kv_prefix_cache_tracks_repeat_context_chains() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut c = cfg();
    c.kv_cache = Some(harmonia::cache::KvCacheConfig::default());
    // Disable the request cache so the repeat re-retrieves from scratch:
    // the generator then sees the identical context segment chain twice
    // and the second prefill must probe into an exact prefix hit.
    c.cache = None;
    let h = deploy(apps::vanilla_rag(), c).unwrap();
    let q: &[u8] = b"prefix cache probe for topic zero";
    let first = h
        .submit(q)
        .recv_timeout(std::time::Duration::from_secs(120))
        .unwrap();
    assert!(first.error.is_none(), "{:?}", first.error);
    let second = h
        .submit(q)
        .recv_timeout(std::time::Duration::from_secs(120))
        .unwrap();
    assert!(second.error.is_none(), "{:?}", second.error);
    // The prefix cache is bookkeeping in front of prefill — it must never
    // change what the engine generates.
    assert_eq!(first.answer, second.answer, "kv prefix cache must not change the answer");
    let report = h.report();
    let snap = report.kv_prefix.expect("kv prefix counters in the live report");
    assert!(snap.insertions >= 2, "each prefill memoizes its chain: {snap:?}");
    assert!(snap.exact_hits >= 1, "repeat context chain did not hit: {snap:?}");
    h.shutdown();
}

#[test]
fn corrective_rag_exercises_conditional_flow() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut c = cfg();
    // One instance per component keeps worker startup tractable.
    c.instances = Some(
        [("grader".to_string(), 1usize)]
            .into_iter()
            .collect::<HashMap<_, _>>(),
    );
    let h = deploy(apps::corrective_rag(), c).unwrap();
    let rxs: Vec<_> = (0..4)
        .map(|i| h.submit(format!("crag question {i}?").as_bytes()))
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(240)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        // hops: retriever + grader + [rewriter + websearch] + generator.
        assert!(r.hops == 3 || r.hops == 5, "hops {}", r.hops);
    }
    let report = h.report();
    assert_eq!(report.completed, 4);
    assert!(report.components.contains_key("grader"));
    h.shutdown();
}

#[test]
fn self_rag_loop_terminates() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let h = deploy(apps::self_rag(), cfg()).unwrap();
    let rx = h.submit(b"loopy question");
    let r = rx.recv_timeout(std::time::Duration::from_secs(240)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    // 1 iteration = 3 hops (retr, gen, critic); each extra iteration adds
    // rewriter + the loop body. Iteration bound 2 → at most 11 hops.
    assert!((3..=11).contains(&r.hops), "hops {}", r.hops);
    h.shutdown();
}

#[test]
fn hybrid_rag_forks_and_joins_live() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let h = deploy(apps::hybrid_rag(), cfg()).unwrap();
    let rx = h.submit(b"what does topic two say?");
    let r = rx.recv_timeout(std::time::Duration::from_secs(240)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    // Branch completions count as hops: retriever + websearch + the
    // joined generator.
    assert_eq!(r.hops, 3, "hops {}", r.hops);
    assert!(!r.answer.is_empty());
    let report = h.report();
    assert_eq!(report.completed, 1);
    // Both branches executed once, and the barrier recorded a release.
    assert_eq!(report.components["retriever"].executions, 1);
    assert_eq!(report.components["websearch"].executions, 1);
    assert_eq!(report.components["generator"].joins, 1);
    h.shutdown();
}

#[test]
fn multiquery_rag_fuses_variant_retrievals_live() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let h = deploy(apps::multiquery_rag(2), cfg()).unwrap();
    let rx = h.submit(b"tell me about topic three");
    let r = rx.recv_timeout(std::time::Duration::from_secs(240)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    // 2 × (rewriter + retriever) + generator.
    assert_eq!(r.hops, 5, "hops {}", r.hops);
    let report = h.report();
    for comp in ["rewriter_q0", "retriever_q1", "generator"] {
        assert!(report.components.contains_key(comp), "missing {comp}");
    }
    assert_eq!(report.components["generator"].joins, 1);
    h.shutdown();
}

#[test]
fn adaptive_rag_classifies_and_routes() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let h = deploy(apps::adaptive_rag(), cfg()).unwrap();
    let rxs: Vec<_> = (0..4)
        .map(|i| h.submit(format!("adaptive question {i} with varied length {}", "x".repeat(i * 7)).as_bytes()))
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(std::time::Duration::from_secs(240)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.hops >= 1);
    }
    let report = h.report();
    assert_eq!(report.completed, 4);
    assert!(report.components.contains_key("classifier"));
    h.shutdown();
}
