//! `harmonia` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   serve <app>              deploy live workers (real XLA artifacts) and
//!                            answer queries from stdin
//!   sim <app> <system> ...   paper-scale cluster simulation
//!   plan <app>               print the LP allocation plan (§3.2)
//!   apps                     list the reference RAG applications
//!   dot [out-dir]            render every registered app to Graphviz DOT
//!                            with LP allocations + modeled latencies

use std::io::BufRead;

use harmonia::alloc::flow::{paper_cluster_budgets, plan_for};
use harmonia::coordinator::controller::{deploy, ControllerConfig};
use harmonia::profile::profile_graph;
use harmonia::runtime::{artifacts_available, default_artifacts_dir};
use harmonia::sim::{run_point, SystemKind};
use harmonia::spec::{apps, to_dot_with, DotOverlay};

/// Every app registered in `apps::by_name`, in presentation order.
const REGISTERED_APPS: [&str; 10] = [
    "v-rag",
    "v-rag-sharded",
    "v-rag-cached",
    "c-rag",
    "s-rag",
    "a-rag",
    "hybrid-rag",
    "hybrid-rag-seq",
    "mq-rag",
    "mq-rag-seq",
];

const USAGE: &str = "usage:
  harmonia apps
  harmonia plan  <v-rag|c-rag|s-rag|a-rag|hybrid-rag|mq-rag|...>
  harmonia sim   <app> <harmonia|langchain|haystack> [rate] [n]
  harmonia serve <app>            (requires `make artifacts`)
  harmonia dot   [out-dir]        (default target/dot)";

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("apps") => {
            println!(
                "{:<12} {:<12} {:<10} {:<9} components",
                "name", "conditional", "recursive", "parallel"
            );
            let mut graphs = apps::all();
            graphs.push(apps::hybrid_rag());
            graphs.push(apps::multiquery_rag(3));
            for g in graphs {
                println!(
                    "{:<12} {:<12} {:<10} {:<9} {}",
                    g.name,
                    g.has_conditionals(),
                    g.has_recursion(),
                    g.has_forks(),
                    g.work_nodes().map(|n| n.name.clone()).collect::<Vec<_>>().join(", ")
                );
            }
        }
        Some("plan") => {
            let app = args.get(1).map(|s| s.as_str()).unwrap_or("c-rag");
            let g = apps::by_name(app).ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
            let plan = plan_for(&g, 2000, 0);
            print!("{}", plan.describe(&g));
            let _ = paper_cluster_budgets();
        }
        Some("sim") => {
            let app = args.get(1).map(|s| s.as_str()).unwrap_or("c-rag");
            let system = match args.get(2).map(|s| s.as_str()).unwrap_or("harmonia") {
                "harmonia" => SystemKind::Harmonia,
                "langchain" => SystemKind::LangChain,
                "haystack" => SystemKind::Haystack,
                o => anyhow::bail!("unknown system {o}"),
            };
            let rate: f64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(64.0);
            let n: usize = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(2000);
            let g = apps::by_name(app).ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
            let r = run_point(system, g, rate, n, Some(2.0), 42);
            println!(
                "{} on {}: throughput {:.2} req/s, mean latency {:.3}s, p95 {:.3}s, SLO violations {:.1}%",
                app,
                system.name(),
                r.report.throughput,
                r.report.mean_latency,
                r.report.p95,
                r.report.slo_violation_rate * 100.0
            );
        }
        Some("serve") => {
            anyhow::ensure!(artifacts_available(), "run `make artifacts` first");
            let app = args.get(1).map(|s| s.as_str()).unwrap_or("v-rag");
            let g = apps::by_name(app).ok_or_else(|| anyhow::anyhow!("unknown app {app}"))?;
            println!("deploying {app} (live XLA workers)... type queries, ctrl-d to exit");
            let h = deploy(g, ControllerConfig::quick(default_artifacts_dir()))?;
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let rx = h.submit(line.trim().as_bytes());
                let r = rx.recv()?;
                match r.error {
                    None => println!(
                        "[{:.3}s, {} stages] {}",
                        r.latency_secs,
                        r.hops,
                        String::from_utf8_lossy(&r.answer)
                    ),
                    Some(e) => println!("error: {e}"),
                }
            }
            h.shutdown();
        }
        Some("dot") => {
            let out = args.get(1).map(|s| s.as_str()).unwrap_or("target/dot");
            std::fs::create_dir_all(out)?;
            for name in REGISTERED_APPS {
                let g = apps::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown app {name}"))?;
                let plan = plan_for(&g, 2000, 0);
                let profile = profile_graph(&g, 2000, 0);
                let overlay = DotOverlay {
                    instances: g
                        .nodes
                        .iter()
                        .map(|n| {
                            if n.id == g.source || n.id == g.sink {
                                None
                            } else {
                                Some(plan.instances(n.id))
                            }
                        })
                        .collect(),
                    modeled_ms: g
                        .nodes
                        .iter()
                        .map(|n| {
                            profile
                                .mean_service
                                .get(&n.id)
                                .copied()
                                .filter(|&m| m > 0.0)
                                .map(|m| m * 1000.0)
                        })
                        .collect(),
                    measured_ms: vec![None; g.nodes.len()],
                };
                let path = format!("{out}/{name}.dot");
                std::fs::write(&path, to_dot_with(&g, &overlay))?;
                println!("wrote {path}");
            }
            println!("render with: dot -Tsvg {out}/<app>.dot -o <app>.svg");
        }
        _ => println!("{USAGE}"),
    }
    Ok(())
}
