//! KV prefix cache — memoization of the generator's context prefill,
//! keyed on the retrieved-context *segment chain*.
//!
//! A RAG prompt is assembled from retrieved documents in rank order
//! (`RagState::doc_ids` with per-doc byte boundaries in
//! `RagState::ctx_segments`), so two requests that retrieve the same
//! leading documents share a KV-cache prefix even when their tails
//! differ. [`KvPrefixCache`] exploits that: after a full prefill it
//! memoizes every prefix of the request's segment chain; a later request
//! probes **longest-prefix-first** and resumes prefill after the deepest
//! cached chain instead of recomputing it — the RAGCache/CacheBlend idea
//! specialized to Patchwork's per-doc segment boundaries.
//!
//! Keying discipline: a chain element is the pair `(doc_id, seg_bytes)`.
//! Two requests whose `ctx_segments` differ — same documents, different
//! truncation — must never share KV state, so the byte length is part of
//! the key, and the match is over the *chain*, not the doc set (order
//! matters: KV attention is positional). Pinned by property tests.
//!
//! Eviction reuses the `cache/` idioms: sharded `Mutex` maps, logical
//! LRU ticks, TTL with expired-first eviction, counters exported through
//! [`crate::metrics::cache`]. Partial-depth hits are recorded in the
//! snapshot's `semantic_hits` slot (the "related entry served" tier);
//! full-chain matches count as exact hits.
//!
//! The modeling side lives in `profile::models`
//! (`kv_prefix_service_factor`, `KV_PREFIX_HIT_COST_FRAC`): the DES and
//! the allocation LP price a hit as a fixed fraction of the prefill,
//! while this structure gives the live path the real lookup — and its
//! [`KvPrefixCache::fold`] digest lets tests prove a cached prefix
//! resumes to exactly the state an uncached prefill would reach.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::metrics::cache::{CacheCounters, CacheSnapshot};

/// One element of a context segment chain: (document id, segment bytes).
pub type KvSegment = (usize, usize);

/// Sizing and policy knobs for the KV prefix cache.
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Max cached prefix entries across all shards (each insert stores
    /// one entry per chain depth, so a depth-k prefill costs k entries).
    pub capacity: usize,
    /// Seconds an entry stays servable; older entries are dropped on
    /// probe and can never serve.
    pub ttl: f64,
    /// Lock shards (concurrency, not correctness; clamped to ≥1).
    pub n_shards: usize,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig { capacity: 4096, ttl: 300.0, n_shards: 8 }
    }
}

/// A successful prefix probe: resume prefill after `depth` chain
/// elements (`bytes` of context already attended), with `state` the
/// digest of the restored KV prefix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPrefixHit {
    /// Matched chain depth (count of leading segments covered).
    pub depth: usize,
    /// Context bytes covered by the cached prefix.
    pub bytes: usize,
    /// Digest of the restored prefix state — equals
    /// [`KvPrefixCache::chain_state`] over the matched prefix, which is
    /// what an uncached prefill of the same prefix computes.
    pub state: u64,
}

struct Entry {
    state: u64,
    bytes: usize,
    inserted_at: f64,
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    entries: HashMap<Vec<u8>, Entry>,
    tick: u64,
}

/// Sharded longest-prefix KV cache. See the module docs.
pub struct KvPrefixCache {
    cfg: KvCacheConfig,
    shards: Vec<Mutex<Shard>>,
    counters: CacheCounters,
}

/// Seed of the KV digest fold (arbitrary non-zero constant).
const KV_FOLD_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

fn encode_prefix(chain: &[KvSegment], depth: usize) -> Vec<u8> {
    let mut key = Vec::with_capacity(depth * 16);
    for &(doc, seg) in &chain[..depth] {
        key.extend_from_slice(&(doc as u64).to_le_bytes());
        key.extend_from_slice(&(seg as u64).to_le_bytes());
    }
    key
}

fn key_hash(key: &[u8]) -> u64 {
    // FNV-1a, as in `query_cache` — stable and dependency-free.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl KvPrefixCache {
    pub fn new(cfg: KvCacheConfig) -> KvPrefixCache {
        let n = cfg.n_shards.max(1);
        KvPrefixCache {
            cfg,
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            counters: CacheCounters::new(),
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Counter snapshot (exported into `RunReport::disagg.kv_prefix`).
    pub fn snapshot(&self) -> CacheSnapshot {
        self.counters.snapshot()
    }

    /// Fold one segment into a KV digest — the deterministic stand-in
    /// for "attend over this segment given the prefix state". Prefix
    /// property: the digest after segments `0..k` depends only on those
    /// segments, so a cached depth-k state plus an uncached fold of the
    /// tail reaches exactly the full-chain state.
    pub fn fold(state: u64, seg: KvSegment) -> u64 {
        let mut h = state ^ (seg.0 as u64).wrapping_mul(0xA076_1D64_78BD_642F);
        h ^= (seg.1 as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB);
        h ^= h >> 32;
        h.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Digest of a full chain from the cold-start state (the "uncached
    /// oracle" of the property tests).
    pub fn chain_state(chain: &[KvSegment]) -> u64 {
        chain.iter().fold(KV_FOLD_SEED, |s, &seg| Self::fold(s, seg))
    }

    /// All prefixes of a chain share the hash of its first element, so a
    /// longest-prefix probe takes a single shard lock.
    fn shard_for(&self, chain: &[KvSegment]) -> usize {
        let key = encode_prefix(chain, 1.min(chain.len()));
        (key_hash(&key) % self.shards.len() as u64) as usize
    }

    fn per_shard_cap(&self) -> usize {
        self.cfg.capacity.div_ceil(self.shards.len()).max(1)
    }

    /// Longest-prefix lookup: the deepest live cached prefix of `chain`,
    /// or `None`. Expired prefixes encountered on the way down are
    /// dropped (counted stale) and never served. A full-depth match is
    /// an exact hit; a shorter one a partial (semantic-slot) hit.
    pub fn lookup(&self, chain: &[KvSegment], now: f64) -> Option<KvPrefixHit> {
        if chain.is_empty() || self.cfg.capacity == 0 {
            self.counters.on_miss();
            return None;
        }
        let si = self.shard_for(chain);
        let mut shard = self.shards[si].lock().expect("kv cache shard poisoned");
        shard.tick += 1;
        let tick = shard.tick;
        let ttl = self.cfg.ttl;
        for depth in (1..=chain.len()).rev() {
            let key = encode_prefix(chain, depth);
            // Tri-state probe, then mutate (the scrutinee borrows the map).
            let probe = match shard.entries.get_mut(&key) {
                Some(e) if now - e.inserted_at <= ttl => {
                    e.last_used = tick;
                    Some(Some(KvPrefixHit { depth, bytes: e.bytes, state: e.state }))
                }
                Some(_) => Some(None), // present but expired
                None => None,
            };
            match probe {
                Some(Some(hit)) => {
                    if depth == chain.len() {
                        self.counters.on_exact_hit();
                    } else {
                        self.counters.on_semantic_hit();
                    }
                    return Some(hit);
                }
                Some(None) => {
                    shard.entries.remove(&key);
                    self.counters.on_stale();
                }
                None => {}
            }
        }
        self.counters.on_miss();
        None
    }

    /// Memoize a finished prefill: every prefix of the chain becomes
    /// servable (prefix-closed storage is what makes longest-prefix
    /// matching correct after partial evictions). One insertion is
    /// counted per call.
    pub fn insert(&self, chain: &[KvSegment], now: f64) {
        if chain.is_empty() || self.cfg.capacity == 0 {
            return;
        }
        let si = self.shard_for(chain);
        let cap = self.per_shard_cap();
        let mut shard = self.shards[si].lock().expect("kv cache shard poisoned");
        shard.tick += 1;
        let tick = shard.tick;
        let mut state = KV_FOLD_SEED;
        let mut bytes = 0usize;
        for depth in 1..=chain.len() {
            let seg = chain[depth - 1];
            state = Self::fold(state, seg);
            bytes += seg.1;
            let key = encode_prefix(chain, depth);
            if shard.entries.len() >= cap && !shard.entries.contains_key(&key) {
                // Expired-first eviction (same rule as `query_cache`):
                // dead entries pin capacity but can never serve.
                let ttl = self.cfg.ttl;
                let expired: Vec<Vec<u8>> = shard
                    .entries
                    .iter()
                    .filter(|(_, e)| now - e.inserted_at > ttl)
                    .map(|(k, _)| k.clone())
                    .collect();
                for k in expired {
                    shard.entries.remove(&k);
                    self.counters.on_stale();
                }
                // Still full of live entries: LRU eviction.
                while shard.entries.len() >= cap {
                    let Some(victim) = shard
                        .entries
                        .iter()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| k.clone())
                    else {
                        break;
                    };
                    shard.entries.remove(&victim);
                    self.counters.on_eviction();
                }
            }
            shard
                .entries
                .insert(key, Entry { state, bytes, inserted_at: now, last_used: tick });
        }
        self.counters.on_insertion();
    }

    /// Live entries across all shards (diagnostics).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|m| m.lock().expect("kv cache shard poisoned").entries.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Assemble a segment chain from the parallel `doc_ids` / `ctx_segments`
/// vectors of `exec::RagState` (truncated to the shorter of the two; the
/// state merge keeps them aligned).
pub fn chain_of(doc_ids: &[usize], ctx_segments: &[usize]) -> Vec<KvSegment> {
    doc_ids.iter().copied().zip(ctx_segments.iter().copied()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    fn chain(rng: &mut Rng, len: usize) -> Vec<KvSegment> {
        (0..len)
            .map(|_| (rng.range_i64(0, 64) as usize, rng.range_i64(16, 512) as usize))
            .collect()
    }

    #[test]
    fn full_chain_hit_restores_the_oracle_state() {
        let c = KvPrefixCache::new(KvCacheConfig::default());
        let ch = vec![(3, 120), (7, 80), (1, 200)];
        assert!(c.lookup(&ch, 0.0).is_none(), "cold cache misses");
        c.insert(&ch, 0.0);
        let hit = c.lookup(&ch, 1.0).expect("hit after insert");
        assert_eq!(hit.depth, 3);
        assert_eq!(hit.bytes, 400);
        assert_eq!(hit.state, KvPrefixCache::chain_state(&ch));
        let s = c.snapshot();
        assert_eq!(s.exact_hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.insertions, 1);
    }

    #[test]
    fn longest_prefix_wins_and_matches_the_prefix_oracle() {
        let c = KvPrefixCache::new(KvCacheConfig::default());
        let cached = vec![(3, 120), (7, 80)];
        c.insert(&cached, 0.0);
        // A longer chain sharing the cached prefix: partial hit at the
        // cached depth, with the state an uncached prefill of that
        // prefix would reach — resuming the fold over the tail lands on
        // the full-chain oracle.
        let probe = vec![(3, 120), (7, 80), (9, 300)];
        let hit = c.lookup(&probe, 1.0).expect("prefix hit");
        assert_eq!(hit.depth, 2);
        assert_eq!(hit.state, KvPrefixCache::chain_state(&cached));
        let resumed = KvPrefixCache::fold(hit.state, probe[2]);
        assert_eq!(resumed, KvPrefixCache::chain_state(&probe));
        assert_eq!(c.snapshot().semantic_hits, 1, "partial depth counts in the partial slot");
    }

    #[test]
    fn differing_segment_boundaries_never_share_state() {
        // Same documents, different truncation: the byte length is part
        // of the key, so no cross-request hit — serving KV computed over
        // a longer segment to a shorter one would corrupt attention.
        let c = KvPrefixCache::new(KvCacheConfig::default());
        c.insert(&[(3, 120), (7, 80)], 0.0);
        assert!(c.lookup(&[(3, 121), (7, 80)], 0.0).is_none());
        // First element matches → depth-1 prefix serves, never deeper.
        let hit = c.lookup(&[(3, 120), (7, 81)], 0.0).expect("depth-1 prefix");
        assert_eq!(hit.depth, 1);
        // Order matters: the same set in a different order is a miss.
        assert!(c.lookup(&[(7, 80), (3, 120)], 0.0).is_none());
    }

    #[test]
    fn cached_prefill_identical_to_uncached_oracle_property() {
        // Satellite property #1: on an exact segment-chain match the
        // cached state equals the uncached oracle's, at every depth.
        property("kv cache == oracle on exact chains", 20, |g| {
            let mut rng = Rng::new(g.i64(0, 1 << 30) as u64);
            let c = KvPrefixCache::new(KvCacheConfig {
                capacity: 4096,
                ttl: 1e9,
                n_shards: g.usize(1, 4),
            });
            let chains: Vec<Vec<KvSegment>> =
                (0..12).map(|_| chain(&mut rng, 1 + (rng.range_i64(0, 5) as usize))).collect();
            for (i, ch) in chains.iter().enumerate() {
                c.insert(ch, i as f64);
            }
            for ch in &chains {
                let hit = c.lookup(ch, 12.0).expect("inserted chain must hit");
                assert_eq!(hit.depth, ch.len());
                assert_eq!(hit.state, KvPrefixCache::chain_state(ch));
                assert_eq!(hit.bytes, ch.iter().map(|s| s.1).sum::<usize>());
            }
        });
    }

    #[test]
    fn never_a_cross_request_hit_when_segments_differ_property() {
        // Satellite property #2: any hit's matched prefix must be a
        // *verbatim* prefix of some inserted chain — mutating one
        // segment length caps the servable depth strictly below the
        // mutation point.
        property("kv cache never crosses segment boundaries", 20, |g| {
            let mut rng = Rng::new(g.i64(0, 1 << 30) as u64);
            let c = KvPrefixCache::new(KvCacheConfig {
                capacity: 4096,
                ttl: 1e9,
                n_shards: 2,
            });
            let ch = chain(&mut rng, 2 + (rng.range_i64(0, 4) as usize));
            c.insert(&ch, 0.0);
            let cut = rng.range_i64(0, ch.len() as i64) as usize;
            let mut mutated = ch.clone();
            mutated[cut].1 += 1; // same doc, different truncation
            match c.lookup(&mutated, 1.0) {
                None => assert_eq!(cut, 0, "a shared non-empty prefix must serve"),
                Some(hit) => {
                    assert!(hit.depth <= cut, "hit depth {} crosses mutation at {cut}", hit.depth);
                    assert_eq!(hit.state, KvPrefixCache::chain_state(&mutated[..hit.depth]));
                }
            }
        });
    }

    #[test]
    fn ttl_and_capacity_never_serve_an_expired_chain_property() {
        // Satellite property #3: whatever the insert/probe schedule, a
        // hit never comes from an entry older than the TTL, and expired
        // entries are dropped (stale) rather than capacity-evicted.
        property("kv cache ttl safety", 16, |g| {
            let ttl = g.f64(1.0, 40.0);
            let c = KvPrefixCache::new(KvCacheConfig {
                capacity: g.usize(4, 64),
                ttl,
                n_shards: g.usize(1, 4),
            });
            let mut rng = Rng::new(g.i64(0, 1 << 30) as u64);
            let mut inserted: Vec<(Vec<KvSegment>, f64)> = Vec::new();
            for _ in 0..16 {
                let ch = chain(&mut rng, 1 + (rng.range_i64(0, 4) as usize));
                let at = rng.range_i64(0, 100) as f64;
                c.insert(&ch, at);
                inserted.push((ch, at));
            }
            let now = rng.range_i64(0, 160) as f64;
            for (ch, _) in &inserted {
                if let Some(hit) = c.lookup(ch, now) {
                    // A hit's prefix must have a live witness insertion:
                    // some chain sharing that prefix, inserted within TTL.
                    let witness = inserted.iter().any(|(c2, at2)| {
                        now - at2 <= ttl
                            && c2.len() >= hit.depth
                            && c2[..hit.depth] == ch[..hit.depth]
                    });
                    assert!(witness, "hit at depth {} without a live insertion", hit.depth);
                }
            }
        });
    }

    #[test]
    fn ttl_expires_and_counts_stale() {
        let c = KvPrefixCache::new(KvCacheConfig { ttl: 10.0, ..Default::default() });
        let ch = vec![(1, 100), (2, 100)];
        c.insert(&ch, 0.0);
        assert!(c.lookup(&ch, 10.0).is_some(), "at TTL still live");
        assert!(c.lookup(&ch, 10.1).is_none(), "past TTL stale");
        assert!(c.snapshot().stale >= 2, "both prefix depths dropped as stale");
        assert!(c.is_empty());
    }

    #[test]
    fn capacity_evicts_lru_prefixes() {
        let c = KvPrefixCache::new(KvCacheConfig { capacity: 2, ttl: 1e9, n_shards: 1 });
        let a = vec![(1, 10)];
        let b = vec![(2, 10)];
        c.insert(&a, 0.0);
        c.insert(&b, 0.0);
        // Touch `a` so `b` is the LRU victim.
        assert!(c.lookup(&a, 0.0).is_some());
        c.insert(&[(3, 10)], 0.0);
        assert!(c.lookup(&a, 0.0).is_some(), "recently used survives");
        assert!(c.lookup(&b, 0.0).is_none(), "LRU victim evicted");
        assert!(c.snapshot().evictions >= 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn chain_of_zips_the_parallel_vectors() {
        assert_eq!(chain_of(&[5, 9], &[120, 80]), vec![(5, 120), (9, 80)]);
        // Misaligned vectors truncate to the shorter side.
        assert_eq!(chain_of(&[5, 9, 11], &[120, 80]), vec![(5, 120), (9, 80)]);
        assert!(chain_of(&[], &[1]).is_empty());
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(KvPrefixCache::new(KvCacheConfig::default()));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    let ch = vec![((t * 7 + i) as usize % 20, 64), (i as usize % 5, 32)];
                    if c.lookup(&ch, i as f64).is_none() {
                        c.insert(&ch, i as f64);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert!(s.insertions > 0 && s.exact_hits + s.semantic_hits > 0);
    }
}
