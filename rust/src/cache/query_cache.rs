//! The sharded, concurrency-safe query cache (exact + semantic tiers).
//!
//! Time is explicit: every operation takes `now` in seconds from an
//! arbitrary epoch. The live path feeds wall-clock seconds, tests and
//! the bench feed a logical clock — TTL behavior is deterministic and
//! property-testable either way.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::cache::{CacheCounters, CacheSnapshot};
use crate::retrieval::SearchResult;

/// Cache sizing and policy knobs (`ControllerConfig::cache` threads these
/// into the live deployment).
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Max entries in the exact tier (across all shards).
    pub exact_capacity: usize,
    /// Max entries in the semantic tier (across all shards).
    pub semantic_capacity: usize,
    /// Seconds an entry stays servable; older entries count as stale and
    /// are dropped on lookup.
    pub ttl: f64,
    /// Cosine-similarity floor for a semantic hit (embeddings are
    /// unit-norm, so this is a dot-product threshold).
    pub sim_threshold: f32,
    /// Lock shards (concurrency, not correctness; clamped to ≥1).
    pub n_shards: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            exact_capacity: 1024,
            // The semantic tier serves a *neighbor's* documents for
            // similar-but-distinct queries — correct answers can change.
            // It is opt-in (capacity 0 = disabled); the default cache is
            // exact-repeat memoization only.
            semantic_capacity: 0,
            ttl: 300.0,
            sim_threshold: 0.92,
            n_shards: 8,
        }
    }
}

struct ExactEntry {
    results: Vec<SearchResult>,
    inserted_at: f64,
    last_used: u64,
}

struct SemanticEntry {
    /// Stable identity (recency is bumped after the scan picks a winner;
    /// positions shift under concurrent eviction, ids do not).
    id: u64,
    embedding: Vec<f32>,
    results: Vec<SearchResult>,
    inserted_at: f64,
    last_used: u64,
}

/// One lock shard: a slice of both tiers plus a logical tick for LRU
/// recency (deterministic — no wall clock involved).
#[derive(Default)]
struct Shard {
    exact: HashMap<Vec<u8>, ExactEntry>,
    semantic: Vec<SemanticEntry>,
    tick: u64,
}

/// Sharded two-tier query cache. See the module docs in [`crate::cache`].
pub struct QueryCache {
    cfg: CacheConfig,
    shards: Vec<Mutex<Shard>>,
    counters: CacheCounters,
    next_sem_id: AtomicU64,
}

/// Canonical form of a query for exact matching: ASCII-lowercased with
/// whitespace runs collapsed to single spaces and outer whitespace
/// trimmed — trivially re-ordered requests ("Foo  bar " vs "foo bar")
/// memoize together without touching semantics.
pub fn normalize_query(query: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(query.len());
    let mut pending_space = false;
    for &b in query {
        if b.is_ascii_whitespace() {
            pending_space = !out.is_empty();
        } else {
            if pending_space {
                out.push(b' ');
                pending_space = false;
            }
            out.push(b.to_ascii_lowercase());
        }
    }
    out
}

fn key_hash(key: &[u8]) -> u64 {
    // FNV-1a: stable, dependency-free, good enough for shard spreading.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

impl QueryCache {
    pub fn new(cfg: CacheConfig) -> QueryCache {
        let n = cfg.n_shards.max(1);
        QueryCache {
            cfg,
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            counters: CacheCounters::new(),
            next_sem_id: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Counter snapshot (exported into `RunReport::cache`).
    pub fn snapshot(&self) -> CacheSnapshot {
        self.counters.snapshot()
    }

    fn shard_for(&self, key: &[u8]) -> usize {
        (key_hash(key) % self.shards.len() as u64) as usize
    }

    fn per_shard_cap(&self, total: usize) -> usize {
        total.div_ceil(self.shards.len()).max(1)
    }

    /// Exact-tier lookup. A hit returns the memoized top-k verbatim; an
    /// exact miss is NOT counted here — the terminal miss for a lookup
    /// is recorded by [`QueryCache::lookup_semantic`], which callers
    /// continue to (it counts the miss even when the tier is disabled).
    pub fn lookup_exact(&self, query: &[u8], now: f64) -> Option<Vec<SearchResult>> {
        let key = normalize_query(query);
        let si = self.shard_for(&key);
        let mut shard = self.shards[si].lock().expect("cache shard poisoned");
        shard.tick += 1;
        let tick = shard.tick;
        // Tri-state probe first, mutate the map after: the match scrutinee
        // holds a &mut borrow of the map for all arms.
        let probe = match shard.exact.get_mut(&key) {
            Some(e) if now - e.inserted_at <= self.cfg.ttl => {
                e.last_used = tick;
                Some(Some(e.results.clone()))
            }
            Some(_) => Some(None), // present but expired
            None => None,
        };
        match probe {
            Some(Some(results)) => {
                self.counters.on_exact_hit();
                Some(results)
            }
            Some(None) => {
                shard.exact.remove(&key);
                self.counters.on_stale();
                None
            }
            None => None,
        }
    }

    /// Semantic-tier lookup with the already-computed query embedding:
    /// returns the results of the most similar live entry at or above the
    /// similarity threshold. Counts the terminal hit/miss for the lookup.
    /// The scan mutates nothing; only the winning entry's recency is
    /// bumped afterwards (by stable id — touching every candidate that
    /// temporarily led the scan would corrupt LRU eviction).
    pub fn lookup_semantic(&self, embedding: &[f32], now: f64) -> Option<Vec<SearchResult>> {
        if self.cfg.semantic_capacity == 0 {
            // Tier disabled: terminal miss without sweeping the locks.
            self.counters.on_miss();
            return None;
        }
        // Scan holds each lock briefly and allocates nothing: only
        // (score, shard, id) is tracked; the winner's results are cloned
        // once in the re-lock step below.
        let mut best: Option<(f32, usize, u64)> = None;
        for (si, m) in self.shards.iter().enumerate() {
            let mut shard = m.lock().expect("cache shard poisoned");
            // Drop expired entries eagerly so they can never be returned.
            let ttl = self.cfg.ttl;
            let before = shard.semantic.len();
            shard.semantic.retain(|e| now - e.inserted_at <= ttl);
            for _ in shard.semantic.len()..before {
                self.counters.on_stale();
            }
            for e in shard.semantic.iter() {
                let s = dot(embedding, &e.embedding);
                let better = match &best {
                    None => true,
                    Some((bs, _, _)) => s > *bs,
                };
                if s >= self.cfg.sim_threshold && better {
                    best = Some((s, si, e.id));
                }
            }
        }
        let served = best.and_then(|(_, si, id)| {
            // Re-lock the winner's shard, refresh its recency, and clone
            // its results; the entry may have been evicted concurrently,
            // in which case the lookup degrades to a miss.
            let mut shard = self.shards[si].lock().expect("cache shard poisoned");
            shard.tick += 1;
            let tick = shard.tick;
            shard.semantic.iter_mut().find(|e| e.id == id).map(|e| {
                e.last_used = tick;
                e.results.clone()
            })
        });
        match served {
            Some(results) => {
                self.counters.on_semantic_hit();
                Some(results)
            }
            None => {
                self.counters.on_miss();
                None
            }
        }
    }

    /// Populate both tiers after an uncached retrieval pass.
    pub fn insert(&self, query: &[u8], embedding: &[f32], results: &[SearchResult], now: f64) {
        let key = normalize_query(query);
        let si = self.shard_for(&key);
        let exact_cap = self.per_shard_cap(self.cfg.exact_capacity);
        let sem_cap = self.per_shard_cap(self.cfg.semantic_capacity);
        let mut shard = self.shards[si].lock().expect("cache shard poisoned");
        shard.tick += 1;
        let tick = shard.tick;
        let mut wrote = false;

        if self.cfg.exact_capacity > 0 {
            if shard.exact.len() >= exact_cap && !shard.exact.contains_key(&key) {
                // Expired entries go first: they pin capacity but can
                // never serve again, and evicting by recency alone can
                // keep a recently-probed-but-expired entry alive while a
                // live one gets dropped. Counted as stale (they died of
                // TTL), not as capacity evictions.
                let ttl = self.cfg.ttl;
                let expired: Vec<Vec<u8>> = shard
                    .exact
                    .iter()
                    .filter(|(_, e)| now - e.inserted_at > ttl)
                    .map(|(k, _)| k.clone())
                    .collect();
                for k in expired {
                    shard.exact.remove(&k);
                    self.counters.on_stale();
                }
                // Still full of *live* entries: LRU eviction.
                if shard.exact.len() >= exact_cap {
                    if let Some(victim) = shard
                        .exact
                        .iter()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| k.clone())
                    {
                        shard.exact.remove(&victim);
                        self.counters.on_eviction();
                    }
                }
            }
            shard.exact.insert(
                key,
                ExactEntry { results: results.to_vec(), inserted_at: now, last_used: tick },
            );
            wrote = true;
        }

        if self.cfg.semantic_capacity > 0 {
            if shard.semantic.len() >= sem_cap {
                // Same expired-first rule as the exact tier.
                let ttl = self.cfg.ttl;
                let before = shard.semantic.len();
                shard.semantic.retain(|e| now - e.inserted_at <= ttl);
                for _ in shard.semantic.len()..before {
                    self.counters.on_stale();
                }
            }
            if shard.semantic.len() >= sem_cap {
                if let Some(victim) = shard
                    .semantic
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(i, _)| i)
                {
                    shard.semantic.swap_remove(victim);
                    self.counters.on_eviction();
                }
            }
            shard.semantic.push(SemanticEntry {
                id: self.next_sem_id.fetch_add(1, Ordering::Relaxed),
                embedding: embedding.to_vec(),
                results: results.to_vec(),
                inserted_at: now,
                last_used: tick,
            });
            wrote = true;
        }
        if wrote {
            self.counters.on_insertion();
        }
    }

    /// Live entries per tier (diagnostics).
    pub fn len(&self) -> (usize, usize) {
        let mut exact = 0;
        let mut sem = 0;
        for m in &self.shards {
            let s = m.lock().expect("cache shard poisoned");
            exact += s.exact.len();
            sem += s.semantic.len();
        }
        (exact, sem)
    }

    pub fn is_empty(&self) -> bool {
        let (e, s) = self.len();
        e == 0 && s == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retrieval::{IvfParams, ShardParams, ShardedIndex};
    use crate::util::proptest::property;
    use crate::workload::corpus::Corpus;
    use crate::workload::queries::{QueryMix, ZipfQueryGen};

    const DIM: usize = 32;

    fn results(ids: &[usize]) -> Vec<SearchResult> {
        ids.iter()
            .map(|&id| SearchResult { id, score: 1.0 - id as f32 * 0.01 })
            .collect()
    }

    #[test]
    fn normalize_collapses_case_and_whitespace() {
        assert_eq!(normalize_query(b"  Foo   BAR "), b"foo bar".to_vec());
        assert_eq!(normalize_query(b"foo bar"), b"foo bar".to_vec());
        assert_eq!(normalize_query(b""), Vec::<u8>::new());
        assert_eq!(normalize_query(b"\t a \n b "), b"a b".to_vec());
    }

    #[test]
    fn exact_hit_returns_identical_results() {
        let c = QueryCache::new(CacheConfig::default());
        let r = results(&[3, 1, 4]);
        let emb = vec![1.0; 4];
        c.insert(b"What is RAG?", &emb, &r, 0.0);
        let got = c.lookup_exact(b"what is  rag?", 1.0).expect("hit");
        assert_eq!(got, r);
        let s = c.snapshot();
        assert_eq!(s.exact_hits, 1);
        assert_eq!(s.insertions, 1);
    }

    #[test]
    fn ttl_expires_entries() {
        let c = QueryCache::new(CacheConfig {
            ttl: 10.0,
            semantic_capacity: 16,
            ..Default::default()
        });
        let emb = vec![1.0, 0.0];
        c.insert(b"q", &emb, &results(&[1]), 0.0);
        assert!(c.lookup_exact(b"q", 10.0).is_some(), "at TTL still live");
        assert!(c.lookup_exact(b"q", 10.1).is_none(), "past TTL stale");
        assert_eq!(c.snapshot().stale, 1);
        // Semantic tier expires too.
        assert!(c.lookup_semantic(&emb, 10.1).is_none());
    }

    #[test]
    fn semantic_tier_disabled_by_default() {
        // The default config is exact-repeat memoization only: a
        // paraphrase must never be served a neighbor's documents unless
        // the operator opts in with semantic_capacity > 0.
        let c = QueryCache::new(CacheConfig::default());
        let emb = vec![1.0, 0.0];
        c.insert(b"orig", &emb, &results(&[7]), 0.0);
        assert!(c.lookup_semantic(&emb, 0.0).is_none(), "identical embedding must still miss");
        let (_, sem) = c.len();
        assert_eq!(sem, 0, "no semantic entries stored");
        assert_eq!(c.snapshot().misses, 1);
    }

    #[test]
    fn exact_capacity_zero_disables_the_exact_tier() {
        let c = QueryCache::new(CacheConfig {
            exact_capacity: 0,
            semantic_capacity: 0,
            ..Default::default()
        });
        let emb = vec![1.0];
        c.insert(b"q", &emb, &results(&[1]), 0.0);
        assert!(c.lookup_exact(b"q", 0.0).is_none());
        assert!(c.is_empty());
        assert_eq!(c.snapshot().insertions, 0, "fully disabled cache records no insertions");
    }

    #[test]
    fn semantic_hit_requires_threshold() {
        let c = QueryCache::new(CacheConfig {
            sim_threshold: 0.9,
            semantic_capacity: 16,
            ..Default::default()
        });
        let a = vec![1.0, 0.0];
        c.insert(b"orig", &a, &results(&[7]), 0.0);
        // Identical embedding: hit.
        assert!(c.lookup_semantic(&a, 1.0).is_some());
        // Orthogonal embedding: miss.
        let b = vec![0.0, 1.0];
        assert!(c.lookup_semantic(&b, 1.0).is_none());
        let s = c.snapshot();
        assert_eq!(s.semantic_hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn semantic_lookup_bumps_only_the_winning_entry() {
        let c = QueryCache::new(CacheConfig {
            exact_capacity: 8,
            semantic_capacity: 2,
            ttl: 1e9,
            sim_threshold: 0.1,
            n_shards: 1,
        });
        c.insert(b"e1", &[1.0, 0.0], &results(&[1]), 0.0);
        c.insert(b"e2", &[0.8, 0.6], &results(&[2]), 0.0);
        // Probe closer to e2: both clear the threshold, e2 wins — only
        // e2's recency may be refreshed.
        let hit = c.lookup_semantic(&[0.6, 0.8], 0.0).expect("hit");
        assert_eq!(hit, results(&[2]));
        // Capacity 2: the next insert must evict the never-serving e1,
        // not the just-served e2 (the bug this test pins: a scan that
        // touches every leading candidate would keep e1 alive).
        c.insert(b"e3", &[0.0, 1.0], &results(&[3]), 0.0);
        let again = c.lookup_semantic(&[0.8, 0.6], 0.0).expect("e2 must survive eviction");
        assert_eq!(again, results(&[2]));
    }

    #[test]
    fn expired_entries_do_not_pin_lru_capacity() {
        // Regression: a dead (TTL-expired) entry used to count toward
        // LRU capacity at insert time — and because eviction keyed on
        // recency alone, a recently-probed-but-expired entry could
        // survive while a *live* entry was evicted. Expired entries must
        // be dropped first (counted stale, not evicted).
        let c = QueryCache::new(CacheConfig {
            exact_capacity: 2,
            semantic_capacity: 0,
            ttl: 10.0,
            sim_threshold: 0.99,
            n_shards: 1,
        });
        let emb = vec![1.0];
        c.insert(b"a", &emb, &results(&[1]), 0.0); // expires at t=10
        c.insert(b"b", &emb, &results(&[2]), 8.0); // expires at t=18
        // Probe "a" while still live: bumps its recency above "b"'s.
        assert!(c.lookup_exact(b"a", 9.0).is_some());
        // t=12: "a" is expired (but most recently used), "b" is live.
        // Inserting "c" at capacity must drop dead "a", not live "b".
        c.insert(b"c", &emb, &results(&[3]), 12.0);
        assert!(c.lookup_exact(b"b", 12.0).is_some(), "live entry evicted for a dead one");
        assert!(c.lookup_exact(b"c", 12.0).is_some());
        assert!(c.lookup_exact(b"a", 12.0).is_none());
        let s = c.snapshot();
        assert!(s.stale >= 1, "expired-drop must count as stale, got {s:?}");
        assert_eq!(s.evictions, 0, "no live entry was capacity-evicted");
        let (exact, _) = c.len();
        assert_eq!(exact, 2);
    }

    #[test]
    fn semantic_tier_drops_expired_before_live_on_insert() {
        let c = QueryCache::new(CacheConfig {
            exact_capacity: 0,
            semantic_capacity: 2,
            ttl: 10.0,
            sim_threshold: 0.9,
            n_shards: 1,
        });
        c.insert(b"old", &[1.0, 0.0], &results(&[1]), 0.0); // dead at t=12
        c.insert(b"live", &[0.0, 1.0], &results(&[2]), 8.0);
        c.insert(b"new", &[0.7, 0.7], &results(&[3]), 12.0);
        // The live entry survived; the expired one was dropped as stale.
        assert!(c.lookup_semantic(&[0.0, 1.0], 12.0).is_some(), "live entry must survive");
        assert!(c.lookup_semantic(&[1.0, 0.0], 12.0).is_none());
        let s = c.snapshot();
        assert!(s.stale >= 1);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn capacity_evicts_lru() {
        let cfg = CacheConfig {
            exact_capacity: 2,
            semantic_capacity: 2,
            ttl: 1e9,
            sim_threshold: 0.99,
            n_shards: 1,
        };
        let c = QueryCache::new(cfg);
        let emb = vec![1.0];
        c.insert(b"a", &emb, &results(&[1]), 0.0);
        c.insert(b"b", &emb, &results(&[2]), 0.0);
        // Touch "a" so "b" is the LRU victim.
        assert!(c.lookup_exact(b"a", 0.0).is_some());
        c.insert(b"c", &emb, &results(&[3]), 0.0);
        assert!(c.lookup_exact(b"a", 0.0).is_some(), "recently used survives");
        assert!(c.lookup_exact(b"b", 0.0).is_none(), "LRU victim evicted");
        assert!(c.lookup_exact(b"c", 0.0).is_some());
        assert!(c.snapshot().evictions >= 1);
        let (exact, _) = c.len();
        assert_eq!(exact, 2);
    }

    /// Build a small sharded index + cache and drive a Zipfian query
    /// stream through both a cached pass and an uncached oracle pass.
    fn cached_vs_oracle_property(seed: u64, n: usize, n_queries: usize) {
        let corpus = Corpus::generate(n, 8, 64, seed);
        let mut vectors = Vec::with_capacity(n * DIM);
        for p in &corpus.passages {
            vectors.extend(Corpus::hash_embed(&p.text, DIM));
        }
        let index = ShardedIndex::build(
            vectors,
            DIM,
            ShardParams { n_shards: 4, ivf: IvfParams::default() },
        );
        let cache = QueryCache::new(CacheConfig {
            exact_capacity: 512,
            semantic_capacity: 0, // exact-repeat identity is the property
            ttl: 1e9,
            sim_threshold: 2.0, // unreachable: cosine ≤ 1
            n_shards: 4,
        });
        let mix = QueryMix { zipf_s: 1.1, repeat_frac: 0.7, pool_size: 16 };
        let mut qg = ZipfQueryGen::new(&corpus, mix, seed ^ 0x51);
        let k = 5;
        let ef = 64;
        for t in 0..n_queries {
            let q = qg.next();
            let now = t as f64;
            let oracle = index.search(&Corpus::hash_embed(&q.text, DIM), k, ef);
            let got = match cache.lookup_exact(&q.text, now) {
                Some(hit) => hit,
                None => {
                    let emb = Corpus::hash_embed(&q.text, DIM);
                    let fresh = index.search(&emb, k, ef);
                    cache.insert(&q.text, &emb, &fresh, now);
                    fresh
                }
            };
            // Bit-identical to the uncached oracle pass: same ids, same
            // scores (the index is deterministic, so a memoized repeat
            // must equal a recomputed one exactly).
            assert_eq!(got.len(), oracle.len());
            for (a, b) in got.iter().zip(&oracle) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.score, b.score);
            }
        }
        assert!(cache.snapshot().exact_hits > 0, "zipf stream must repeat");
    }

    #[test]
    fn cached_pass_identical_to_uncached_oracle_on_exact_repeats() {
        property("cache == oracle on repeats", 6, |g| {
            let seed = g.i64(0, 1 << 20) as u64;
            let n = g.usize(120, 400);
            cached_vs_oracle_property(seed, n, 60);
        });
    }

    #[test]
    fn never_returns_expired_or_below_threshold_entries() {
        property("ttl + threshold safety", 12, |g| {
            let ttl = g.f64(1.0, 50.0);
            let threshold = g.f64(0.3, 0.99) as f32;
            let cfg = CacheConfig {
                exact_capacity: 64,
                semantic_capacity: 64,
                ttl,
                sim_threshold: threshold,
                n_shards: g.usize(1, 4),
            };
            let c = QueryCache::new(cfg);
            // Insert entries with random ages; probe with random vectors.
            let mut entries: Vec<(Vec<u8>, Vec<f32>, f64)> = Vec::new();
            for i in 0..12 {
                let name = format!("query number {i}").into_bytes();
                let mut emb: Vec<f32> = (0..8).map(|_| g.f64(-1.0, 1.0) as f32).collect();
                let norm = emb.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                emb.iter_mut().for_each(|x| *x /= norm);
                let at = g.f64(0.0, 100.0);
                c.insert(&name, &emb, &results(&[i]), at);
                entries.push((name, emb, at));
            }
            let now = g.f64(0.0, 160.0);
            for (name, emb, at) in &entries {
                if now - at > ttl {
                    assert!(
                        c.lookup_exact(name, now).is_none(),
                        "expired exact entry returned (age {})",
                        now - at
                    );
                }
                if let Some(hit) = c.lookup_semantic(emb, now) {
                    // A semantic hit must come from a live entry at or
                    // above the threshold; verify one exists.
                    let witness = entries
                        .iter()
                        .any(|(_, e2, at2)| now - at2 <= ttl && dot(emb, e2) >= threshold);
                    assert!(witness, "semantic hit without a qualifying entry");
                    assert!(!hit.is_empty());
                }
            }
        });
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(QueryCache::new(CacheConfig::default()));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    let key = format!("q{}", (t * 7 + i) % 50).into_bytes();
                    let emb = vec![1.0, t as f32, i as f32];
                    if c.lookup_exact(&key, i as f64).is_none() {
                        let r = [SearchResult { id: i as usize, score: 0.5 }];
                        c.insert(&key, &emb, &r, i as f64);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert!(s.insertions > 0 && s.exact_hits > 0);
    }
}
