//! Request cache — memoization of the embed→retrieve prefix.
//!
//! Real RAG traffic is heavily skewed (a few queries account for most of
//! the volume), so the cheapest retrieval capacity is work never redone.
//! [`QueryCache`] short-circuits the retrieval stage with two tiers:
//!
//! * an **exact tier** keyed on normalized query text — a repeat of a
//!   previously served query returns the memoized top-k verbatim
//!   (bit-identical to the uncached pass, pinned by property tests);
//! * a **semantic tier** that reuses the *already computed* query
//!   embedding to probe an LRU of recent `(embedding, top-k)` entries
//!   under a cosine-similarity threshold — near-duplicates (paraphrases,
//!   typo variants) reuse their neighbor's results, in the spirit of the
//!   semantic caches (RAGCache / GPTCache) in PAPERS.md.
//!
//! Both tiers apply TTL + capacity (LRU) eviction and export
//! hit/miss/stale counters through [`crate::metrics::cache`]. The cache
//! is sharded by key hash and safe for concurrent use from the worker
//! threads of `exec::components`.
//!
//! The modeling side lives in `profile::models`
//! (`cache_service_factor`, `zipf_hit_rate`): the profiler, the
//! allocation LP, and the DES all see the same cache-adjusted α for the
//! retrieval pool, making the cache the first component whose effective
//! capacity *grows* with load skew — the per-component scaling
//! heterogeneity the paper argues a unified serving layer must model.
//!
//! [`kv_prefix`] applies the same discipline one stage later: a KV
//! prefix cache over the generator's retrieved-context segment chains
//! (`RagState::ctx_segments`), collapsing repeat-heavy prefill the way
//! the query cache collapses repeat retrieval. Its modeled twin is
//! `profile::models::kv_prefix_service_factor`.

pub mod kv_prefix;
pub mod query_cache;

pub use kv_prefix::{chain_of, KvCacheConfig, KvPrefixCache, KvPrefixHit, KvSegment};
pub use query_cache::{normalize_query, CacheConfig, QueryCache};
