//! Workload substrate: synthetic corpus, query/trace generation.
//!
//! Substitutes the paper's datasets (LMSYS-Chat-1M chats, Wiki-DPR
//! passages) with deterministic generators that match the *statistics*
//! the serving results depend on: Poisson arrivals, heavy-tailed prompt
//! and generation lengths, k ∈ [100, 300] retrieved documents, and an
//! A-RAG complexity mix.

pub mod corpus;
pub mod queries;
pub mod trace;

pub use corpus::{Corpus, Passage};
pub use queries::{Query, QueryGen, QueryMix, ZipfQueryGen};
pub use trace::{Request, Trace, TraceConfig};
