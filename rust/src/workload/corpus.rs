//! Synthetic passage corpus — the Wiki-DPR substitute.
//!
//! Passages are deterministic byte strings drawn from a topic-structured
//! generator: the corpus has `n_topics` topics; each passage belongs to a
//! topic and its text is topic-template bytes plus noise. This gives the
//! vector index real cluster structure (so IVF probing and the
//! `search_ef` recall/latency tradeoff behave like they do on real
//! embeddings) while remaining fully reproducible.

use crate::util::rng::Rng;

/// One passage of up-to-`max_len` bytes (Wiki-DPR uses 100-word passages).
#[derive(Clone, Debug)]
pub struct Passage {
    pub id: usize,
    pub topic: usize,
    pub text: Vec<u8>,
}

/// A synthetic corpus with topic structure.
pub struct Corpus {
    pub passages: Vec<Passage>,
    pub n_topics: usize,
}

impl Corpus {
    /// Generate `n` passages over `n_topics` topics with text length
    /// `len`. Deterministic for (n, n_topics, len, seed).
    pub fn generate(n: usize, n_topics: usize, len: usize, seed: u64) -> Corpus {
        assert!(n_topics > 0 && n > 0);
        let mut rng = Rng::new(seed);
        // Topic templates: fixed byte patterns the topic's passages share.
        let templates: Vec<Vec<u8>> = (0..n_topics)
            .map(|_| (0..len).map(|_| (rng.below(64) + 32) as u8).collect())
            .collect();
        let passages = (0..n)
            .map(|id| {
                let topic = rng.index(n_topics);
                let mut text = templates[topic].clone();
                // 30% of bytes are passage-specific noise.
                for b in text.iter_mut() {
                    if rng.chance(0.3) {
                        *b = (rng.below(64) + 32) as u8;
                    }
                }
                Passage { id, topic, text }
            })
            .collect();
        Corpus { passages, n_topics }
    }

    pub fn len(&self) -> usize {
        self.passages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.passages.is_empty()
    }

    /// Deterministic pseudo-embedding of a byte string: topic structure is
    /// preserved because similar bytes produce similar vectors. Used by
    /// the pure-Rust path (sim/benches); the live path uses the real
    /// XLA embedder artifact instead.
    pub fn hash_embed(text: &[u8], dim: usize) -> Vec<f32> {
        let mut v = vec![0f32; dim];
        // Sum of per-byte pseudo-random unit contributions: nearby texts
        // (sharing most bytes) get nearby embeddings.
        for (i, &b) in text.iter().enumerate() {
            let h = splitmix(b as u64 ^ ((i as u64) << 8));
            for (j, vj) in v.iter_mut().enumerate() {
                let g = splitmix(h ^ (j as u64).wrapping_mul(0x9E3779B97F4A7C15));
                // map to [-1, 1]
                *vj += ((g >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32;
            }
        }
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
        for x in v.iter_mut() {
            *x /= norm;
        }
        v
    }
}

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn corpus_deterministic() {
        let a = Corpus::generate(50, 5, 64, 1);
        let b = Corpus::generate(50, 5, 64, 1);
        for (pa, pb) in a.passages.iter().zip(&b.passages) {
            assert_eq!(pa.text, pb.text);
            assert_eq!(pa.topic, pb.topic);
        }
    }

    #[test]
    fn same_topic_passages_are_closer() {
        let c = Corpus::generate(200, 4, 64, 2);
        let embs: Vec<(usize, Vec<f32>)> = c
            .passages
            .iter()
            .map(|p| (p.topic, Corpus::hash_embed(&p.text, 32)))
            .collect();
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..60 {
            for j in (i + 1)..60 {
                let s = dot(&embs[i].1, &embs[j].1);
                if embs[i].0 == embs[j].0 {
                    same.push(s);
                } else {
                    diff.push(s);
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(
            mean(&same) > mean(&diff) + 0.1,
            "same {} diff {}",
            mean(&same),
            mean(&diff)
        );
    }

    #[test]
    fn embeddings_unit_norm() {
        let e = Corpus::hash_embed(b"hello world", 64);
        let norm: f32 = e.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn embed_is_deterministic_and_input_sensitive() {
        let a = Corpus::hash_embed(b"query one", 32);
        let b = Corpus::hash_embed(b"query one", 32);
        let c = Corpus::hash_embed(b"query two", 32);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
