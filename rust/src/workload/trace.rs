//! Request traces: Poisson arrivals with heavy-tailed per-request work —
//! the LMSYS-Chat-1M substitute (matched length statistics, not text).

use crate::profile::models::RequestFeatures;
use crate::util::rng::Rng;

/// One admitted request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
    pub features: RequestFeatures,
    /// SLO deadline (arrival + slo_latency), if an SLO is configured.
    pub deadline: Option<f64>,
}

/// Trace generation parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/second) of the Poisson process.
    pub rate: f64,
    /// Number of requests to generate.
    pub n: usize,
    /// SLO latency budget in seconds (None = no deadline).
    pub slo: Option<f64>,
    /// Prompt length lognormal (mu, sigma) in log-token space.
    pub prompt_mu: f64,
    pub prompt_sigma: f64,
    /// Generation length lognormal (mu, sigma).
    pub gen_mu: f64,
    pub gen_sigma: f64,
    /// Retrieved-docs range [k_lo, k_hi] (paper: 100–300).
    pub k_lo: usize,
    pub k_hi: usize,
    /// A-RAG complexity mix (simple, standard, complex); must sum to 1.
    pub complexity_mix: [f64; 3],
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 16.0,
            n: 1000,
            slo: None,
            // exp(4.1) ≈ 60 tokens median prompt, heavy tail.
            prompt_mu: 4.1,
            prompt_sigma: 0.6,
            // exp(3.7) ≈ 40 tokens median generation.
            gen_mu: 3.7,
            gen_sigma: 0.7,
            k_lo: 100,
            k_hi: 300,
            complexity_mix: [0.2, 0.5, 0.3],
        }
    }
}

impl TraceConfig {
    /// Sample one request's generation length alone (the profiler's
    /// co-batch draws for static-batching inflation use this, so the
    /// batch-maximum estimate comes from the same distribution the trace
    /// generator emits).
    pub fn sample_gen_len(&self, rng: &mut Rng) -> usize {
        rng.lognormal(self.gen_mu, self.gen_sigma).round().clamp(4.0, 96.0) as usize
    }

    /// Sample one request's features.
    pub fn sample_features(&self, rng: &mut Rng) -> RequestFeatures {
        let prompt_len = rng
            .lognormal(self.prompt_mu, self.prompt_sigma)
            .round()
            .clamp(4.0, 127.0) as usize;
        let gen_len = self.sample_gen_len(rng);
        let k_docs = rng.range_i64(self.k_lo as i64, self.k_hi as i64) as usize;
        let complexity = rng.weighted(&self.complexity_mix) as u8;
        RequestFeatures { prompt_len, gen_len, k_docs, complexity }
    }

    /// Generate the full trace (deterministic for a seed).
    pub fn generate(&self, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(self.n);
        for id in 0..self.n {
            t += rng.exp(self.rate);
            let features = self.sample_features(&mut rng);
            requests.push(Request {
                id,
                arrival: t,
                features,
                deadline: self.slo.map(|s| t + s),
            });
        }
        Trace { requests, rate: self.rate }
    }
}

/// A generated trace.
#[derive(Clone, Debug)]
pub struct Trace {
    pub requests: Vec<Request>,
    pub rate: f64,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Duration from first to last arrival.
    pub fn span(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        self.requests.last().unwrap().arrival - self.requests[0].arrival
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn poisson_rate_is_respected() {
        let cfg = TraceConfig { rate: 50.0, n: 20_000, ..Default::default() };
        let tr = cfg.generate(0);
        let empirical = (tr.len() - 1) as f64 / tr.span();
        assert!((empirical - 50.0).abs() / 50.0 < 0.05, "rate {empirical}");
    }

    #[test]
    fn arrivals_monotone_nondecreasing() {
        let tr = TraceConfig::default().generate(1);
        for w in tr.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn k_docs_in_paper_range() {
        let tr = TraceConfig::default().generate(2);
        for r in &tr.requests {
            assert!((100..=300).contains(&r.features.k_docs));
        }
    }

    #[test]
    fn deadlines_set_when_slo_configured() {
        let cfg = TraceConfig { slo: Some(2.0), n: 10, ..Default::default() };
        let tr = cfg.generate(3);
        for r in &tr.requests {
            let d = r.deadline.unwrap();
            assert!((d - r.arrival - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn trace_deterministic_per_seed() {
        let cfg = TraceConfig::default();
        let a = cfg.generate(9);
        let b = cfg.generate(9);
        for (ra, rb) in a.requests.iter().zip(&b.requests) {
            assert_eq!(ra.arrival, rb.arrival);
            assert_eq!(ra.features.prompt_len, rb.features.prompt_len);
        }
    }

    #[test]
    fn feature_distributions_property() {
        property("trace features sane", 20, |g| {
            let cfg = TraceConfig {
                rate: g.f64(1.0, 100.0),
                n: 50,
                ..Default::default()
            };
            let tr = cfg.generate(g.i64(0, 1 << 30) as u64);
            for r in &tr.requests {
                assert!(r.features.prompt_len >= 4 && r.features.prompt_len < 128);
                assert!(r.features.gen_len >= 4 && r.features.gen_len <= 96);
                assert!(r.features.complexity <= 2);
            }
        });
    }

    #[test]
    fn complexity_mix_matches_config() {
        let cfg = TraceConfig { n: 30_000, ..Default::default() };
        let tr = cfg.generate(4);
        let mut counts = [0usize; 3];
        for r in &tr.requests {
            counts[r.features.complexity as usize] += 1;
        }
        for (i, &expected) in cfg.complexity_mix.iter().enumerate() {
            let got = counts[i] as f64 / tr.len() as f64;
            assert!((got - expected).abs() < 0.02, "class {i}: {got} vs {expected}");
        }
    }
}
