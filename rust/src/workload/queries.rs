//! Query generation: topic-targeted byte-string queries, so retrieval has
//! ground truth (a query about topic T should retrieve topic-T passages —
//! the recall axis of the Fig. 4 `search_ef` study), plus a Zipfian
//! repeat-query stream ([`ZipfQueryGen`]) for the skewed workloads the
//! request cache (`cache::QueryCache`) exists to exploit.

use crate::util::rng::Rng;
use crate::workload::corpus::Corpus;

/// A user query tied to a ground-truth topic.
#[derive(Clone, Debug)]
pub struct Query {
    pub id: usize,
    pub topic: usize,
    pub text: Vec<u8>,
}

/// Generates queries resembling passages of a chosen topic.
pub struct QueryGen<'a> {
    corpus: &'a Corpus,
    rng: Rng,
    next_id: usize,
}

impl<'a> QueryGen<'a> {
    pub fn new(corpus: &'a Corpus, seed: u64) -> Self {
        QueryGen { corpus, rng: Rng::new(seed), next_id: 0 }
    }

    /// A query is a perturbed excerpt of a random passage of its topic.
    pub fn next(&mut self) -> Query {
        let topic = self.rng.index(self.corpus.n_topics);
        self.next_with_topic(topic)
    }

    pub fn next_with_topic(&mut self, topic: usize) -> Query {
        // Pick a passage of this topic (corpus topics are dense enough
        // that a few tries suffice; fall back to any passage).
        let mut base = None;
        for _ in 0..64 {
            let p = self.rng.choose(&self.corpus.passages);
            if p.topic == topic {
                base = Some(p);
                break;
            }
        }
        let p = base.unwrap_or_else(|| self.rng.choose(&self.corpus.passages));
        let mut text = p.text[..p.text.len().min(48)].to_vec();
        for b in text.iter_mut() {
            if self.rng.chance(0.15) {
                *b = (self.rng.below(64) + 32) as u8;
            }
        }
        let q = Query { id: self.next_id, topic: p.topic, text };
        self.next_id += 1;
        q
    }
}

/// Skew knobs for a repeat-heavy query stream: with probability
/// `repeat_frac` the next query re-draws from a fixed pool of
/// `pool_size` known queries with rank popularity ∝ 1/rank^`zipf_s`
/// (rank 1 hottest); otherwise it is a fresh unique query. `zipf_s = 0`
/// makes repeats uniform over the pool; larger s concentrates traffic on
/// the head — the axis the `fig04c_cache_hit_curve` bench sweeps. The
/// steady-state cache hit rate this induces is
/// `profile::models::zipf_hit_rate`.
#[derive(Clone, Copy, Debug)]
pub struct QueryMix {
    pub zipf_s: f64,
    pub repeat_frac: f64,
    pub pool_size: usize,
}

impl Default for QueryMix {
    fn default() -> Self {
        QueryMix { zipf_s: 1.0, repeat_frac: 0.7, pool_size: 1024 }
    }
}

/// Zipfian repeat-query generator: wraps [`QueryGen`] with a popularity
/// pool. Deterministic for (corpus, mix, seed); emitted queries carry
/// fresh unique ids even when their text repeats (a repeat is a new
/// request for the same content, which is exactly what a request cache
/// sees in production).
pub struct ZipfQueryGen<'a> {
    base: QueryGen<'a>,
    pool: Vec<Query>,
    /// CDF over pool ranks (precomputed; sampled by binary search).
    cdf: Vec<f64>,
    repeat_frac: f64,
    rng: Rng,
    next_id: usize,
}

impl<'a> ZipfQueryGen<'a> {
    pub fn new(corpus: &'a Corpus, mix: QueryMix, seed: u64) -> Self {
        let mut base = QueryGen::new(corpus, seed);
        let pool_size = mix.pool_size.max(1);
        let pool: Vec<Query> = (0..pool_size).map(|_| base.next()).collect();
        let mut cdf = Vec::with_capacity(pool_size);
        let mut acc = 0.0;
        for rank in 1..=pool_size {
            acc += (rank as f64).powf(-mix.zipf_s);
            cdf.push(acc);
        }
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        ZipfQueryGen {
            base,
            pool,
            cdf,
            repeat_frac: mix.repeat_frac.clamp(0.0, 1.0),
            rng: Rng::new(seed ^ 0x21F),
            next_id: 0,
        }
    }

    /// Sample a pool rank from the Zipf CDF.
    fn sample_rank(&mut self) -> usize {
        let u = self.rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.pool.len() - 1)
    }

    /// Next query: a Zipf-weighted repeat with probability `repeat_frac`,
    /// a fresh query otherwise.
    pub fn next(&mut self) -> Query {
        let mut q = if self.rng.chance(self.repeat_frac) {
            let rank = self.sample_rank();
            self.pool[rank].clone()
        } else {
            self.base.next()
        };
        q.id = self.next_id;
        self.next_id += 1;
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::corpus::Corpus;

    #[test]
    fn queries_carry_topics() {
        let c = Corpus::generate(100, 4, 64, 0);
        let mut qg = QueryGen::new(&c, 1);
        let qs: Vec<Query> = (0..50).map(|_| qg.next()).collect();
        let topics: std::collections::HashSet<usize> = qs.iter().map(|q| q.topic).collect();
        assert!(topics.len() > 1, "should cover multiple topics");
        assert!(qs.iter().all(|q| q.topic < 4));
        // ids are unique and increasing
        for (i, q) in qs.iter().enumerate() {
            assert_eq!(q.id, i);
        }
    }

    #[test]
    fn zipf_stream_is_skewed_and_deterministic() {
        let c = Corpus::generate(200, 4, 64, 0);
        let mix = QueryMix { zipf_s: 1.2, repeat_frac: 0.8, pool_size: 64 };
        let mut a = ZipfQueryGen::new(&c, mix, 9);
        let mut b = ZipfQueryGen::new(&c, mix, 9);
        let mut freq: std::collections::HashMap<Vec<u8>, usize> = std::collections::HashMap::new();
        for i in 0..2000 {
            let qa = a.next();
            let qb = b.next();
            assert_eq!(qa.text, qb.text, "deterministic for a seed");
            assert_eq!(qa.id, i, "fresh unique ids");
            *freq.entry(qa.text).or_insert(0) += 1;
        }
        // Skew: the hottest query dominates; total repeats near repeat_frac.
        let mut counts: Vec<usize> = freq.values().copied().collect();
        counts.sort_unstable_by(|x, y| y.cmp(x));
        assert!(counts[0] > 2000 / 64, "head rank must beat uniform: {}", counts[0]);
        let repeats: usize = counts.iter().filter(|&&c| c > 1).map(|&c| c - 1).sum();
        let frac = repeats as f64 / 2000.0;
        assert!((0.6..0.95).contains(&frac), "repeat fraction {frac}");
    }

    #[test]
    fn higher_zipf_s_concentrates_mass_on_the_head() {
        let c = Corpus::generate(200, 4, 64, 1);
        let head_mass = |s: f64| -> usize {
            let mix = QueryMix { zipf_s: s, repeat_frac: 1.0, pool_size: 256 };
            let mut g = ZipfQueryGen::new(&c, mix, 5);
            let mut freq: std::collections::HashMap<Vec<u8>, usize> =
                std::collections::HashMap::new();
            for _ in 0..4000 {
                *freq.entry(g.next().text).or_insert(0) += 1;
            }
            let mut counts: Vec<usize> = freq.values().copied().collect();
            counts.sort_unstable_by(|x, y| y.cmp(x));
            counts.iter().take(10).sum()
        };
        let flat = head_mass(0.2);
        let skewed = head_mass(1.5);
        assert!(
            skewed > flat + 400,
            "top-10 mass must grow with zipf_s: {skewed} vs {flat}"
        );
    }

    #[test]
    fn zero_repeat_frac_never_repeats_pool() {
        let c = Corpus::generate(100, 4, 64, 2);
        let mix = QueryMix { zipf_s: 1.0, repeat_frac: 0.0, pool_size: 8 };
        let mut g = ZipfQueryGen::new(&c, mix, 3);
        // With repeat_frac = 0 every emission comes from the base
        // generator; ids are sequential and the stream advances.
        let qs: Vec<Query> = (0..50).map(|_| g.next()).collect();
        for (i, q) in qs.iter().enumerate() {
            assert_eq!(q.id, i);
        }
    }

    #[test]
    fn query_embedding_near_its_topic() {
        let c = Corpus::generate(400, 4, 64, 3);
        let mut qg = QueryGen::new(&c, 2);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let q = qg.next();
            let qe = Corpus::hash_embed(&q.text, 32);
            // Nearest passage by brute force should share the topic (mostly).
            let mut best = (f32::NEG_INFINITY, 0usize);
            for p in &c.passages {
                let pe = Corpus::hash_embed(&p.text, 32);
                let s: f32 = qe.iter().zip(&pe).map(|(a, b)| a * b).sum();
                if s > best.0 {
                    best = (s, p.topic);
                }
            }
            if best.1 == q.topic {
                hits += 1;
            }
        }
        assert!(hits as f64 / trials as f64 > 0.7, "topic hit rate {hits}/{trials}");
    }
}
