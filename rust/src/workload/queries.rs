//! Query generation: topic-targeted byte-string queries, so retrieval has
//! ground truth (a query about topic T should retrieve topic-T passages —
//! the recall axis of the Fig. 4 `search_ef` study).

use crate::util::rng::Rng;
use crate::workload::corpus::Corpus;

/// A user query tied to a ground-truth topic.
#[derive(Clone, Debug)]
pub struct Query {
    pub id: usize,
    pub topic: usize,
    pub text: Vec<u8>,
}

/// Generates queries resembling passages of a chosen topic.
pub struct QueryGen<'a> {
    corpus: &'a Corpus,
    rng: Rng,
    next_id: usize,
}

impl<'a> QueryGen<'a> {
    pub fn new(corpus: &'a Corpus, seed: u64) -> Self {
        QueryGen { corpus, rng: Rng::new(seed), next_id: 0 }
    }

    /// A query is a perturbed excerpt of a random passage of its topic.
    pub fn next(&mut self) -> Query {
        let topic = self.rng.index(self.corpus.n_topics);
        self.next_with_topic(topic)
    }

    pub fn next_with_topic(&mut self, topic: usize) -> Query {
        // Pick a passage of this topic (corpus topics are dense enough
        // that a few tries suffice; fall back to any passage).
        let mut base = None;
        for _ in 0..64 {
            let p = self.rng.choose(&self.corpus.passages);
            if p.topic == topic {
                base = Some(p);
                break;
            }
        }
        let p = base.unwrap_or_else(|| self.rng.choose(&self.corpus.passages));
        let mut text = p.text[..p.text.len().min(48)].to_vec();
        for b in text.iter_mut() {
            if self.rng.chance(0.15) {
                *b = (self.rng.below(64) + 32) as u8;
            }
        }
        let q = Query { id: self.next_id, topic: p.topic, text };
        self.next_id += 1;
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::corpus::Corpus;

    #[test]
    fn queries_carry_topics() {
        let c = Corpus::generate(100, 4, 64, 0);
        let mut qg = QueryGen::new(&c, 1);
        let qs: Vec<Query> = (0..50).map(|_| qg.next()).collect();
        let topics: std::collections::HashSet<usize> = qs.iter().map(|q| q.topic).collect();
        assert!(topics.len() > 1, "should cover multiple topics");
        assert!(qs.iter().all(|q| q.topic < 4));
        // ids are unique and increasing
        for (i, q) in qs.iter().enumerate() {
            assert_eq!(q.id, i);
        }
    }

    #[test]
    fn query_embedding_near_its_topic() {
        let c = Corpus::generate(400, 4, 64, 3);
        let mut qg = QueryGen::new(&c, 2);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let q = qg.next();
            let qe = Corpus::hash_embed(&q.text, 32);
            // Nearest passage by brute force should share the topic (mostly).
            let mut best = (f32::NEG_INFINITY, 0usize);
            for p in &c.passages {
                let pe = Corpus::hash_embed(&p.text, 32);
                let s: f32 = qe.iter().zip(&pe).map(|(a, b)| a * b).sum();
                if s > best.0 {
                    best = (s, p.topic);
                }
            }
            if best.1 == q.topic {
                hits += 1;
            }
        }
        assert!(hits as f64 / trials as f64 > 0.7, "topic hit rate {hits}/{trials}");
    }
}
