//! The Fig. 8 LP formulation.
//!
//! Variables: f_{i,j} per edge, r_{i,k} per (node, resource).
//!
//!   max Σ_{(u,t)∈E} f_{u,t}                                (sink flow)
//!   s.t. Σ_i r_{i,k} ≤ C_k                     ∀k          (budgets)
//!        Σ_u f_{u,i} ≤ Σ_k α_{i,k} r_{i,k}     ∀i          (capacity)
//!        f_{i,j} = p_{i,j} γ_i Σ_u f_{u,i}     ∀(i,j)      (branching)
//!        f, r ≥ 0
//!
//! Recursion (back edges) keeps the flow system linear: the fixed-point of
//! the conservation equations is encoded directly, so a loop with gain <1
//! yields finite equilibrium flow, matching `PipelineGraph::visit_rates`.
//!
//! Parallel dataflow stays linear too: `Fork` edges carry **full flow**
//! (the profiler reports p = 1 per branch — every branch is work the plan
//! must provision), and a join node's inflow is scaled by 1/branches
//! (`PipelineGraph::join_in_scale`) in both its capacity constraint and
//! its outgoing conservation rows, because the barrier merges the sibling
//! subtasks back into one request.

use std::collections::HashMap;

use crate::lp::{LpModel, Sense};
use crate::lp::simplex::Status;
use crate::profile::models::{kv_prefix_service_factor, GenPlacement, KvTransferModel};
use crate::profile::Profile;
use crate::spec::graph::{ComponentKind, NodeId, PipelineGraph, ResourceKind};

use super::plan::AllocationPlan;

/// A fully-specified allocation problem instance.
pub struct FlowProblem<'a> {
    pub graph: &'a PipelineGraph,
    /// Profiled parameters (α, p, γ).
    pub profile: &'a Profile,
    /// Resource budgets C_k for the whole cluster.
    pub budgets: Vec<(ResourceKind, f64)>,
    /// Generator task placement. `Collocated` (the default) builds
    /// exactly the pre-split formulation; `Disaggregated` gives every
    /// generator separate prefill/decode resource columns coupled by an
    /// explicit KV-handoff flow variable, so each phase is sized by its
    /// own α and the transfer cost is priced — the LP can refuse the
    /// split when transfer dominates (RAGO's "where placement wins").
    pub placement: GenPlacement,
    /// KV-transfer cost model charged to disaggregated handoffs.
    pub kv: KvTransferModel,
    /// Expected KV prefix-cache hit rate discounting prefill work
    /// (disaggregated only; 0 = no prefix cache).
    pub kv_prefix_hit: f64,
}

#[derive(Debug)]
pub enum AllocError {
    Infeasible,
    Unbounded,
    Solver(String),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::Infeasible => write!(f, "allocation LP infeasible"),
            AllocError::Unbounded => write!(f, "allocation LP unbounded"),
            AllocError::Solver(s) => write!(f, "LP solver error: {s}"),
        }
    }
}

impl std::error::Error for AllocError {}

impl<'a> FlowProblem<'a> {
    pub fn new(
        graph: &'a PipelineGraph,
        profile: &'a Profile,
        budgets: Vec<(ResourceKind, f64)>,
    ) -> Self {
        FlowProblem {
            graph,
            profile,
            budgets,
            placement: GenPlacement::Collocated,
            kv: KvTransferModel::paper_interconnect(),
            kv_prefix_hit: 0.0,
        }
    }

    /// Price the generator under an explicit placement / interconnect /
    /// prefix-cache operating point. `Collocated` is a no-op relative to
    /// [`FlowProblem::new`].
    pub fn with_placement(
        mut self,
        placement: GenPlacement,
        kv: KvTransferModel,
        kv_prefix_hit: f64,
    ) -> Self {
        self.placement = placement;
        self.kv = kv;
        self.kv_prefix_hit = kv_prefix_hit.clamp(0.0, 1.0);
        self
    }

    /// Does this node get split prefill/decode columns?
    fn disagg(&self, id: NodeId, kind: &ComponentKind) -> bool {
        self.placement == GenPlacement::Disaggregated
            && matches!(kind, ComponentKind::Generator)
            && self.profile.gen_split.get(&id).is_some_and(|s| s.total() > 0.0)
    }

    /// Build and solve the LP; returns the optimal plan.
    pub fn solve(&self) -> Result<AllocationPlan, AllocError> {
        let g = self.graph;
        let mut m = LpModel::new();

        // Edge-flow variables; objective = flow into sink.
        let mut f_vars = Vec::with_capacity(g.edges.len());
        for (i, e) in g.edges.iter().enumerate() {
            let obj = if e.to == g.sink { 1.0 } else { 0.0 };
            f_vars.push(m.var(
                format!("f_{}_{}", g.node(e.from).name, g.node(e.to).name),
                obj,
            ));
            let _ = i;
        }

        // Resource variables r_{i,k,s}: one column per (node, resource,
        // shard). Unsharded nodes have a single shard (s = 0); sharded
        // components (retrieval scatter-gather) get an independent column
        // per shard so the allocator sizes each shard's replica pool on
        // its own — the paper's "unique scalability characteristics"
        // applied to the index partitions.
        let mut r_vars: HashMap<(NodeId, ResourceKind), Vec<crate::lp::model::Var>> =
            HashMap::new();
        // Disaggregated generators get a second column set: r_vars holds
        // the prefill pool, r_dec_vars the decode pool. Both draw on the
        // same budgets; everything else about the node (inflow,
        // conservation) is shared.
        let mut r_dec_vars: HashMap<(NodeId, ResourceKind), Vec<crate::lp::model::Var>> =
            HashMap::new();
        for node in g.work_nodes() {
            let s_count = node.shards.max(1);
            let split = self.disagg(node.id, &node.kind);
            for &(k, _) in &node.resources {
                let vars: Vec<_> = (0..s_count)
                    .map(|s| {
                        let tag = if split { "rpre" } else { "r" };
                        m.var(format!("{tag}_{}_{}_{s}", node.name, k.name()), 0.0)
                    })
                    .collect();
                r_vars.insert((node.id, k), vars);
                if split {
                    let dvars: Vec<_> = (0..s_count)
                        .map(|s| m.var(format!("rdec_{}_{}_{s}", node.name, k.name()), 0.0))
                        .collect();
                    r_dec_vars.insert((node.id, k), dvars);
                }
            }
        }

        // Budgets: Σ_{i,s} r_{i,k,s} ≤ C_k (prefill and decode pools both
        // bill the same budget line).
        for &(k, cap) in &self.budgets {
            let terms: Vec<_> = r_vars
                .iter()
                .chain(r_dec_vars.iter())
                .filter(|((_, rk), _)| *rk == k)
                .flat_map(|(_, vars)| vars.iter().map(|&v| (v, 1.0)))
                .collect();
            if !terms.is_empty() {
                m.constrain(terms, Sense::Le, cap);
            }
        }

        // Node capacity. The paper's Fig. 8 writes Σ_u f_{u,i} ≤
        // Σ_k α_{i,k} r_{i,k}; for components whose instances bundle
        // several resources (a retriever needs its cores AND its RAM)
        // summing over k would double-count capacity — the LP could buy
        // all throughput from CPU and skip RAM, breaking the rounding to
        // instances. We use the Leontief form instead: one constraint per
        // demanded resource, Σ_u f_{u,i} ≤ α_{i,k} r_{i,k} ∀k, which
        // keeps the model linear and forces proportional bundles.
        // Join inflow scales (1/branches at barriers, 1 elsewhere) and
        // the in-edge index both come from the shared analysis bundle,
        // resolved once for the capacity and conservation rows.
        // `Adjacency` returns edge indices in declaration order — the
        // same order the old per-row edge scans produced — so the LP it
        // builds is bit-identical to the pre-analysis formulation.
        let az = g.analyze();
        let mut h_vars: HashMap<NodeId, crate::lp::model::Var> = HashMap::new();
        for node in g.work_nodes() {
            // Join nodes: the barrier merges `branches` sibling arrivals
            // into one request, so the workload each unit of capacity
            // must absorb is the scaled inflow.
            let in_scale = az.join_scales[node.id.0];
            let inflow: Vec<_> = az
                .adj
                .in_edges(node.id)
                .iter()
                .map(|&i| (f_vars[i], in_scale))
                .collect();
            if inflow.is_empty() {
                continue;
            }
            if self.disagg(node.id, &node.kind) {
                // Disaggregated generator: the phases are serial per
                // request but capacity-independent across requests, so
                // each gets its own Leontief rows. An explicit handoff
                // variable h carries the prefill→decode KV flow:
                //
                //   h = Σ_u f_{u,i} · in_scale        (every prefill ships)
                //   Σ_u f_{u,i} · in_scale ≤ α_pre r_pre,k,s   ∀k,s
                //   h ≤ α_dec r_dec,k,s                        ∀k,s
                //
                // α_pre prices effective prefill work — the profiled
                // split's prefill mean discounted by the expected
                // prefix-cache hit rate, plus the KV transfer the prefill
                // instance is busy shipping. α_dec prices the decode mean
                // alone. Both derive from the same profiled aggregate α,
                // rescaled by total/phase, so Collocated and Disaggregated
                // agree whenever transfer is free and the cache is cold.
                let s = self.profile.gen_split[&node.id];
                let p_eff = s.prefill * kv_prefix_service_factor(self.kv_prefix_hit)
                    + self.kv.cost(s.prompt_tokens.round() as usize);
                let h = m.var(format!("h_{}", node.name), 0.0);
                h_vars.insert(node.id, h);
                let mut conserve = inflow.clone();
                conserve.push((h, -1.0));
                // Σ inflow·in_scale − h = 0  (written h-major for clarity)
                m.constrain(conserve, Sense::Eq, 0.0);
                for &(k, _) in &node.resources {
                    let a = self.profile.alpha_for(node.id, k);
                    if a <= 0.0 {
                        continue;
                    }
                    let a_pre = if p_eff > 0.0 { a * s.total() / p_eff } else { 0.0 };
                    let a_dec = if s.decode > 0.0 { a * s.total() / s.decode } else { 0.0 };
                    if a_pre > 0.0 {
                        for &rv in &r_vars[&(node.id, k)] {
                            let mut terms = inflow.clone();
                            terms.push((rv, -a_pre));
                            m.constrain(terms, Sense::Le, 0.0);
                        }
                    }
                    if a_dec > 0.0 {
                        for &rv in &r_dec_vars[&(node.id, k)] {
                            m.constrain(vec![(h, 1.0), (rv, -a_dec)], Sense::Le, 0.0);
                        }
                    }
                }
                continue;
            }
            // For sharded nodes every request visits *all* shards, so each
            // shard pool must individually keep up with the full inflow:
            // Σ_u f_{u,i} ≤ α_{i,k} r_{i,k,s}  ∀k, ∀s. The profiled α is
            // per-shard already (the profiler applies the calibrated shard
            // service factor), so no extra scaling appears here; the LP
            // naturally sizes all shard pools equally, and the total
            // resource bill matches the unsharded formulation up to the
            // scatter-gather overhead.
            for &(k, _) in &node.resources {
                let a = self.profile.alpha_for(node.id, k);
                if a > 0.0 {
                    for &rv in &r_vars[&(node.id, k)] {
                        let mut terms = inflow.clone();
                        terms.push((rv, -a));
                        m.constrain(terms, Sense::Le, 0.0);
                    }
                }
            }
        }

        // Branch conservation: f_{i,j} = p_{i,j} γ_i s_i Σ_u f_{u,i} for
        // every edge leaving a work node (s_i = the join inflow scale,
        // 1 everywhere else); edges leaving the source carry the admitted
        // flow λ (a free variable we name `lambda`). Fork edges arrive
        // here with p = 1 from the profiler — each branch receives the
        // node's full outflow.
        let lambda = m.var("lambda", 0.0);
        for (i, e) in g.edges.iter().enumerate() {
            let p = self.profile.edge_probs[i];
            if e.from == g.source {
                // f_source,j = p * lambda
                m.constrain(vec![(f_vars[i], 1.0), (lambda, -p)], Sense::Eq, 0.0);
            } else {
                let gamma = self.profile.gamma.get(&e.from).copied().unwrap_or(1.0);
                let in_scale = az.join_scales[e.from.0];
                let mut terms = vec![(f_vars[i], 1.0)];
                for &j in az.adj.in_edges(e.from) {
                    terms.push((f_vars[j], -p * gamma * in_scale));
                }
                m.constrain(terms, Sense::Eq, 0.0);
            }
        }

        let sol = m.solve().map_err(|e| AllocError::Solver(e.to_string()))?;
        match sol.status {
            Status::Optimal => {}
            Status::Infeasible => return Err(AllocError::Infeasible),
            Status::Unbounded => return Err(AllocError::Unbounded),
        }

        let mut resources = HashMap::new();
        let mut shard_resources = HashMap::new();
        for ((node, k), vars) in &r_vars {
            let mut vals: Vec<f64> = vars.iter().map(|v| sol.x[v.0]).collect();
            // Fold the decode pool into the node totals so budget
            // accounting and instance rounding see the full bill; the
            // per-pool split is reported separately via `gen_pools`.
            if let Some(dvars) = r_dec_vars.get(&(*node, *k)) {
                for (slot, dv) in vals.iter_mut().zip(dvars) {
                    *slot += sol.x[dv.0];
                }
            }
            let total: f64 = vals.iter().sum();
            resources.insert((*node, *k), total);
            shard_resources.insert((*node, *k), vals);
        }
        let edge_flows = f_vars.iter().map(|v| sol.x[v.0]).collect();
        let mut plan = AllocationPlan::from_lp(
            g,
            self.profile,
            resources,
            shard_resources,
            edge_flows,
            sol.objective,
            sol.pivots,
        );
        // Report the per-pool split: instances = max over resources of
        // ceil(r_pool / demand), each pool staffed (≥ 1) whenever the node
        // carries flow — an empty prefill or decode pool would deadlock
        // the handoff chain.
        for node in g.work_nodes() {
            let Some(&h) = h_vars.get(&node.id) else { continue };
            let mut n_pre = 0usize;
            let mut n_dec = 0usize;
            for &(k, demand) in &node.resources {
                if demand <= 0.0 {
                    continue;
                }
                let pre: f64 = r_vars[&(node.id, k)].iter().map(|v| sol.x[v.0]).sum();
                let dec: f64 = r_dec_vars[&(node.id, k)].iter().map(|v| sol.x[v.0]).sum();
                n_pre = n_pre.max((pre / demand).ceil() as usize);
                n_dec = n_dec.max((dec / demand).ceil() as usize);
            }
            plan.gen_pools.insert(node.id, (n_pre.max(1), n_dec.max(1)));
            plan.gen_handoff.insert(node.id, sol.x[h.0]);
        }
        Ok(plan)
    }
}

/// Default cluster budgets matching the paper's testbed: 4 nodes × (32
/// CPU cores, 8 GPUs, 256 GiB RAM).
pub fn paper_cluster_budgets() -> Vec<(ResourceKind, f64)> {
    vec![
        (ResourceKind::Cpu, 4.0 * 32.0),
        (ResourceKind::Gpu, 4.0 * 8.0),
        (ResourceKind::Ram, 4.0 * 256.0),
    ]
}

/// Convenience: profile a graph and solve with the paper's budgets.
pub fn plan_for(graph: &PipelineGraph, samples: usize, seed: u64) -> AllocationPlan {
    let profile = crate::profile::profile_graph(graph, samples, seed);
    FlowProblem::new(graph, &profile, paper_cluster_budgets())
        .solve()
        .expect("paper apps are feasible")
}

/// Is this node's primary demand on the GPU?
pub fn gpu_node(kind: &ComponentKind) -> bool {
    kind.gpu_bound()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::profile_graph;
    use crate::spec::apps;

    #[test]
    fn vrag_allocation_is_balanced() {
        let g = apps::vanilla_rag();
        let plan = plan_for(&g, 2000, 0);
        assert!(plan.throughput > 0.0);
        // Both stages must receive capacity.
        let retr = g.node_by_name("retriever").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        assert!(plan.instances(retr) >= 1);
        assert!(plan.instances(gen) >= 1);
    }

    #[test]
    fn crag_gives_grader_more_gpus_than_generator() {
        // §4.3: grader ≈1.8× generator runtime → more graders than
        // generators (paper: 5 graders / 3 generators).
        let g = apps::corrective_rag();
        let plan = plan_for(&g, 4000, 1);
        let grader = g.node_by_name("grader").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let rg = plan.resource(grader, ResourceKind::Gpu);
        let rgen = plan.resource(gen, ResourceKind::Gpu);
        assert!(
            rg > rgen,
            "grader GPUs {rg} should exceed generator GPUs {rgen}"
        );
        let ratio = rg / rgen;
        assert!((1.2..2.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn sharded_retriever_gets_independent_per_shard_pools() {
        let g = apps::sharded_vanilla_rag(4);
        let plan = plan_for(&g, 2000, 0);
        assert!(plan.throughput > 0.0);
        let retr = g.node_by_name("retriever").unwrap().id;
        let per_shard = plan.shard_instance_counts(retr);
        assert_eq!(per_shard.len(), 4, "one replica pool per shard");
        assert!(per_shard.iter().all(|&c| c >= 1), "every shard staffed: {per_shard:?}");
        assert_eq!(
            plan.instances(retr),
            per_shard.iter().sum::<usize>(),
            "component total = sum of shard pools"
        );
        // Deployable units = complete replica sets (min across pools).
        assert_eq!(plan.units(retr), *per_shard.iter().min().unwrap());
        // Unsharded nodes keep a single pool and units == instances.
        let gen = g.node_by_name("generator").unwrap().id;
        assert_eq!(plan.shard_instance_counts(gen).len(), 1);
        assert_eq!(plan.units(gen), plan.instances(gen));
    }

    #[test]
    fn cached_retriever_lifts_the_throughput_ceiling() {
        // The profiler hands the LP a cache-adjusted α for the retrieval
        // pool (hits cost ~5% of a pass). Under the paper budgets,
        // unsharded V-RAG is RAM-bound at the retriever (112 GiB per
        // whole-corpus replica against 1 TiB); with a hot cache the
        // retrieval pool only has to absorb the miss traffic, so the
        // binding constraint moves to the GPUs and the LP's end-to-end
        // ceiling rises — effective retrieval capacity grows with load
        // skew.
        let plain = plan_for(&apps::vanilla_rag(), 3000, 7);
        let cached = plan_for(&apps::cached_vanilla_rag(1.3, 0.8, 2048, 4096), 3000, 7);
        assert!(
            cached.throughput > plain.throughput * 1.2,
            "cached ceiling {} should clearly exceed plain {}",
            cached.throughput,
            plain.throughput
        );
        // The plan still staffs both stages.
        let g = apps::cached_vanilla_rag(1.3, 0.8, 2048, 4096);
        for name in ["retriever", "generator"] {
            let id = g.node_by_name(name).unwrap().id;
            assert!(cached.instances(id) >= 1, "{name} unstaffed");
        }
    }

    #[test]
    fn sharded_vrag_matches_vrag_throughput() {
        // Sharding retrieval must not cost end-to-end throughput: v-rag
        // is generator-bound under the paper budgets, and the scatter-
        // gather overhead only taxes the (cheap) CPU side.
        let sharded = plan_for(&apps::sharded_vanilla_rag(4), 2000, 3);
        let full = plan_for(&apps::vanilla_rag(), 2000, 3);
        assert!(
            sharded.throughput > full.throughput * 0.9,
            "sharded {} vs unsharded {}",
            sharded.throughput,
            full.throughput
        );
    }

    #[test]
    fn hybrid_fork_provisions_both_branches_at_full_flow() {
        let g = apps::hybrid_rag();
        let plan = plan_for(&g, 2000, 0);
        assert!(plan.throughput > 0.0);
        // Every branch is staffed — forks carry full flow per branch.
        for name in ["retriever", "websearch", "generator"] {
            let id = g.node_by_name(name).unwrap().id;
            assert!(plan.instances(id) >= 1, "{name} unstaffed");
        }
        // Both fork edges carry the same (full) flow as the sink edge:
        // branch flow == λ == throughput.
        let sink_flow: f64 = g
            .edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.to == g.sink)
            .map(|(i, _)| plan.edge_flows[i])
            .sum();
        for (i, e) in g.edges.iter().enumerate() {
            if e.is_fork() {
                assert!(
                    (plan.edge_flows[i] - sink_flow).abs() < 1e-6 * sink_flow.max(1.0),
                    "fork edge flow {} vs sink flow {sink_flow}",
                    plan.edge_flows[i]
                );
            }
        }
        // Join conservation: the generator's summed inflow is
        // branches × λ, but its outflow (after the barrier) is λ.
        let gen = g.node_by_name("generator").unwrap().id;
        let inflow: f64 = g
            .edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.to == gen)
            .map(|(i, _)| plan.edge_flows[i])
            .sum();
        assert!(
            (inflow - 2.0 * sink_flow).abs() < 1e-6 * inflow.max(1.0),
            "join inflow {inflow} vs 2λ {}",
            2.0 * sink_flow
        );
    }

    #[test]
    fn parallel_and_serialized_hybrids_reach_similar_ceilings() {
        // Same nodes, same per-visit work: the LP's *throughput* ceiling
        // is resource-bound, so the fork (a latency structure) must not
        // change it materially. The latency win is the DES's to show.
        let par = plan_for(&apps::hybrid_rag(), 2000, 5);
        let seq = plan_for(&apps::hybrid_rag_sequential(), 2000, 5);
        let ratio = par.throughput / seq.throughput;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
        // Multi-query: every variant is full-flow work in both shapes.
        let mq = plan_for(&apps::multiquery_rag(3), 2000, 6);
        assert!(mq.throughput > 0.0);
        let g = apps::multiquery_rag(3);
        for i in 0..3 {
            let id = g.node_by_name(&format!("retriever_q{i}")).unwrap().id;
            assert!(mq.instances(id) >= 1, "variant {i} unstaffed");
        }
    }

    #[test]
    fn collocated_placement_is_the_identity_formulation() {
        // `with_placement(Collocated, …)` must build the exact same LP as
        // `new` — same columns, same rows — so the knob is inert by
        // default, mirroring the DES's golden-trace discipline.
        use crate::profile::models::{GenPlacement, KvTransferModel};
        let g = apps::vanilla_rag();
        let profile = profile_graph(&g, 2000, 11);
        let budgets = paper_cluster_budgets();
        let a = FlowProblem::new(&g, &profile, budgets.clone()).solve().unwrap();
        let b = FlowProblem::new(&g, &profile, budgets)
            .with_placement(GenPlacement::Collocated, KvTransferModel::paper_interconnect(), 0.0)
            .solve()
            .unwrap();
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        assert!(b.gen_pools.is_empty() && b.gen_handoff.is_empty());
        for (key, v) in &a.resources {
            assert_eq!(v.to_bits(), b.resources[key].to_bits());
        }
    }

    #[test]
    fn disagg_handoff_conserves_flow_under_forks() {
        // The explicit KV-handoff variable must carry exactly the
        // generator's scaled inflow — prefill-pool outflow equals
        // decode-pool inflow — including at a join, where the barrier
        // merges `branches` sibling arrivals into one request (hybrid
        // RAG: 2 fork branches × λ inflow, handoff = λ).
        use crate::profile::models::{GenPlacement, KvTransferModel};
        let g = apps::hybrid_rag();
        let profile = profile_graph(&g, 2000, 13);
        let plan = FlowProblem::new(&g, &profile, paper_cluster_budgets())
            .with_placement(GenPlacement::Disaggregated, KvTransferModel::paper_interconnect(), 0.0)
            .solve()
            .unwrap();
        assert!(plan.throughput > 0.0);
        let gen = g.node_by_name("generator").unwrap().id;
        let h = plan.gen_handoff[&gen];
        let sink_flow: f64 = g
            .edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.to == g.sink)
            .map(|(i, _)| plan.edge_flows[i])
            .sum();
        assert!(
            (h - sink_flow).abs() < 1e-6 * sink_flow.max(1.0),
            "handoff {h} vs λ {sink_flow}"
        );
        // Both pools staffed: an empty pool would deadlock the chain.
        let (pre, dec) = plan.pools(gen).unwrap();
        assert!(pre >= 1 && dec >= 1, "pools ({pre}, {dec})");
        // Decode dominates the split at the trace's token mix.
        assert!(dec >= pre, "decode pool {dec} should dominate prefill {pre}");
    }

    #[test]
    fn lp_chooses_collocated_when_transfer_dominates() {
        // The placement economics the LP must see (RAGO Fig. "where each
        // placement wins"): on the reference fabric the split is
        // near-free; on a pathologically slow fabric the prefill pool
        // burns its capacity shipping KV and the disaggregated ceiling
        // collapses below collocated — the signal a placement search
        // needs to refuse the split.
        use crate::profile::models::{GenPlacement, KvTransferModel};
        let g = apps::vanilla_rag();
        let profile = profile_graph(&g, 3000, 17);
        let budgets = paper_cluster_budgets();
        let col = FlowProblem::new(&g, &profile, budgets.clone()).solve().unwrap();
        let fast = FlowProblem::new(&g, &profile, budgets.clone())
            .with_placement(GenPlacement::Disaggregated, KvTransferModel::paper_interconnect(), 0.0)
            .solve()
            .unwrap();
        // Free-ish fabric: phase α's rescale from the same aggregate, so
        // the total resource bill per unit flow is preserved up to the
        // (tiny) transfer term.
        assert!(
            fast.throughput > 0.97 * col.throughput,
            "fast-fabric disagg {} vs collocated {}",
            fast.throughput,
            col.throughput
        );
        let slow_fabric = KvTransferModel { scale: 500.0, ..KvTransferModel::paper_interconnect() };
        let slow = FlowProblem::new(&g, &profile, budgets.clone())
            .with_placement(GenPlacement::Disaggregated, slow_fabric, 0.0)
            .solve()
            .unwrap();
        assert!(
            slow.throughput < 0.9 * col.throughput,
            "slow-fabric disagg {} should fall below collocated {}",
            slow.throughput,
            col.throughput
        );
        // A hot prefix cache pulls the other way: prefill work shrinks,
        // the ceiling meets or beats collocated on the reference fabric.
        let hot = FlowProblem::new(&g, &profile, budgets)
            .with_placement(GenPlacement::Disaggregated, KvTransferModel::paper_interconnect(), 0.9)
            .solve()
            .unwrap();
        assert!(hot.throughput >= fast.throughput - 1e-6);
    }

    #[test]
    fn budget_constraints_respected() {
        let g = apps::adaptive_rag();
        let profile = profile_graph(&g, 2000, 2);
        let budgets = paper_cluster_budgets();
        let plan = FlowProblem::new(&g, &profile, budgets.clone()).solve().unwrap();
        for &(k, cap) in &budgets {
            let used: f64 = g.work_nodes().map(|n| plan.resource(n.id, k)).sum();
            assert!(used <= cap + 1e-6, "{}: {used} > {cap}", k.name());
        }
    }

    #[test]
    fn throughput_scales_with_budget() {
        let g = apps::self_rag();
        let profile = profile_graph(&g, 2000, 3);
        let small = FlowProblem::new(
            &g,
            &profile,
            vec![
                (ResourceKind::Cpu, 32.0),
                (ResourceKind::Gpu, 4.0),
                (ResourceKind::Ram, 256.0),
            ],
        )
        .solve()
        .unwrap();
        let large = FlowProblem::new(
            &g,
            &profile,
            vec![
                (ResourceKind::Cpu, 128.0),
                (ResourceKind::Gpu, 16.0),
                (ResourceKind::Ram, 1024.0),
            ],
        )
        .solve()
        .unwrap();
        assert!(
            large.throughput > small.throughput * 2.0,
            "small {} large {}",
            small.throughput,
            large.throughput
        );
    }

    #[test]
    fn flow_conservation_in_solution() {
        let g = apps::corrective_rag();
        let profile = profile_graph(&g, 3000, 4);
        let plan = FlowProblem::new(&g, &profile, paper_cluster_budgets())
            .solve()
            .unwrap();
        // Outflow of grader ≈ inflow (γ=1): relevant branch + rewrite branch.
        let grader = g.node_by_name("grader").unwrap().id;
        let inflow: f64 = g
            .edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.to == grader)
            .map(|(i, _)| plan.edge_flows[i])
            .sum();
        let outflow: f64 = g
            .edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.from == grader)
            .map(|(i, _)| plan.edge_flows[i])
            .sum();
        assert!((inflow - outflow).abs() < 1e-6 * inflow.max(1.0));
    }

    #[test]
    fn lp_edge_flows_match_the_analysis_flow_table() {
        // One flow computation, two consumers: the LP's per-edge optimum
        // must equal λ × the analysis layer's unit edge flows wherever
        // the profiled edge probabilities are exact (no conditionals —
        // fork and unit-probability edges profile to exactly 1.0, so the
        // two derivations share identical inputs).
        for name in ["v-rag", "hybrid-rag", "mq-rag"] {
            let g = apps::by_name(name).unwrap();
            let az = g.analyze();
            let plan = plan_for(&g, 2000, 21);
            let lambda = plan.throughput;
            assert!(lambda > 0.0, "{name}");
            for (i, f) in plan.edge_flows.iter().enumerate() {
                let want = lambda * az.edge_flows[i];
                assert!(
                    (f - want).abs() < 1e-6 * lambda,
                    "{name} edge {i}: LP {f} vs λ·analysis {want}"
                );
            }
        }
    }

    #[test]
    fn zero_budget_is_zero_throughput() {
        let g = apps::vanilla_rag();
        let profile = profile_graph(&g, 500, 5);
        let plan = FlowProblem::new(
            &g,
            &profile,
            vec![
                (ResourceKind::Cpu, 0.0),
                (ResourceKind::Gpu, 0.0),
                (ResourceKind::Ram, 0.0),
            ],
        )
        .solve()
        .unwrap();
        assert!(plan.throughput.abs() < 1e-9);
    }
}
