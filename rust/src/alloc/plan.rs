//! Allocation plans: the solver's continuous r_{i,k} rounded into concrete
//! per-component instance counts (respecting per-instance demands and
//! `base_instances` floors), plus the flow solution for diagnostics.

use std::collections::HashMap;

use crate::profile::Profile;
use crate::spec::graph::{NodeId, PipelineGraph, ResourceKind};

/// A deployable allocation.
#[derive(Clone, Debug)]
pub struct AllocationPlan {
    /// Continuous resource assignment r_{i,k} from the LP (summed across
    /// shards for sharded components).
    pub resources: HashMap<(NodeId, ResourceKind), f64>,
    /// Rounded instances per component (summed across shards).
    pub instance_counts: HashMap<NodeId, usize>,
    /// Rounded replica count per shard (len == the node's `shards`; a
    /// single entry for unsharded components).
    pub shard_instances: HashMap<NodeId, Vec<usize>>,
    /// Optimal edge flows f_{i,j} (requests/sec).
    pub edge_flows: Vec<f64>,
    /// Optimal end-to-end throughput (flow into sink, requests/sec).
    pub throughput: f64,
    /// Simplex pivots (Fig. 12 diagnostics).
    pub pivots: usize,
    /// Disaggregated generator pools — (prefill, decode) instance counts
    /// per generator node. Empty unless the LP was solved with
    /// `GenPlacement::Disaggregated` (`FlowProblem::with_placement`).
    pub gen_pools: HashMap<NodeId, (usize, usize)>,
    /// Optimal KV-handoff flow (req/s) per disaggregated generator: the
    /// LP's explicit prefill→decode coupling variable. Conservation
    /// demands it equal the node's scaled inflow — pinned by test.
    pub gen_handoff: HashMap<NodeId, f64>,
}

impl AllocationPlan {
    pub(crate) fn from_lp(
        graph: &PipelineGraph,
        _profile: &Profile,
        resources: HashMap<(NodeId, ResourceKind), f64>,
        shard_resources: HashMap<(NodeId, ResourceKind), Vec<f64>>,
        edge_flows: Vec<f64>,
        throughput: f64,
        pivots: usize,
    ) -> AllocationPlan {
        // Per shard: instances = max over resources of
        // ceil(r_{i,k,s} / demand_{i,k}); every shard of a sharded
        // component keeps ≥1 replica (a shard with no replica would drop
        // its slice of the corpus). The component total is floored at
        // base_instances.
        let mut instance_counts = HashMap::new();
        let mut shard_instances = HashMap::new();
        for node in graph.work_nodes() {
            let s_count = node.shards.max(1);
            let mut per_shard = vec![0usize; s_count];
            for (s, slot) in per_shard.iter_mut().enumerate() {
                let mut n_inst = 0usize;
                for &(k, demand) in &node.resources {
                    if demand <= 0.0 {
                        continue;
                    }
                    let r = shard_resources
                        .get(&(node.id, k))
                        .and_then(|v| v.get(s))
                        .copied()
                        .unwrap_or(0.0);
                    n_inst = n_inst.max((r / demand).ceil() as usize);
                }
                *slot = if s_count > 1 { n_inst.max(1) } else { n_inst };
            }
            let raw: usize = per_shard.iter().sum();
            let total = raw.max(node.base_instances).max(1);
            if s_count == 1 {
                per_shard[0] = total;
            } else if total > raw {
                // Distribute the base_instances floor shortfall round-robin
                // so `instances == Σ shard pools` holds for sharded nodes.
                for i in 0..(total - raw) {
                    per_shard[i % s_count] += 1;
                }
            }
            instance_counts.insert(node.id, total);
            shard_instances.insert(node.id, per_shard);
        }
        AllocationPlan {
            resources,
            instance_counts,
            shard_instances,
            edge_flows,
            throughput,
            pivots,
            gen_pools: HashMap::new(),
            gen_handoff: HashMap::new(),
        }
    }

    /// Disaggregated (prefill, decode) pool sizes for a node, if the plan
    /// split it.
    pub fn pools(&self, node: NodeId) -> Option<(usize, usize)> {
        self.gen_pools.get(&node).copied()
    }

    /// Continuous resource units assigned to a node.
    pub fn resource(&self, node: NodeId, k: ResourceKind) -> f64 {
        self.resources.get(&(node, k)).copied().unwrap_or(0.0)
    }

    /// Concrete instance count for a node (summed across shards).
    pub fn instances(&self, node: NodeId) -> usize {
        self.instance_counts.get(&node).copied().unwrap_or(0)
    }

    /// Replica counts per shard for a node (empty if the node is unknown;
    /// a single entry for unsharded components).
    pub fn shard_instance_counts(&self, node: NodeId) -> &[usize] {
        self.shard_instances.get(&node).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Deployable scatter-gather units. A request to a sharded component
    /// must touch one replica of EVERY shard, so a schedulable "unit" is
    /// a complete replica set — the number of such sets is the minimum
    /// across the shard pools (a partial set cannot serve). Unsharded
    /// nodes: same as [`AllocationPlan::instances`]. One unit occupies
    /// `shards` per-replica resource bundles.
    pub fn units(&self, node: NodeId) -> usize {
        match self.shard_instances.get(&node) {
            Some(v) if v.len() > 1 => v.iter().copied().min().unwrap_or(0),
            _ => self.instances(node),
        }
    }

    /// A uniform baseline plan (the Haystack/Ray substitute): divide each
    /// resource budget evenly across the components demanding it.
    pub fn uniform(graph: &PipelineGraph, budgets: &[(ResourceKind, f64)]) -> AllocationPlan {
        let mut resources = HashMap::new();
        for &(k, cap) in budgets {
            let takers: Vec<_> = graph
                .work_nodes()
                .filter(|n| n.demand_for(k) > 0.0)
                .map(|n| n.id)
                .collect();
            if takers.is_empty() {
                continue;
            }
            let share = cap / takers.len() as f64;
            for id in takers {
                resources.insert((id, k), share);
            }
        }
        let mut instance_counts = HashMap::new();
        let mut shard_instances = HashMap::new();
        for node in graph.work_nodes() {
            let mut n_inst = usize::MAX;
            let mut any = false;
            for &(k, demand) in &node.resources {
                if demand <= 0.0 {
                    continue;
                }
                any = true;
                let r = resources.get(&(node.id, k)).copied().unwrap_or(0.0);
                // Uniform split must respect *all* demands simultaneously
                // → min over resources (an instance needs its full bundle).
                n_inst = n_inst.min((r / demand).floor() as usize);
            }
            let n_inst = if any { n_inst } else { 1 };
            let total = n_inst.max(node.base_instances).max(1);
            // Baselines are shard-blind: spread the replicas round-robin,
            // but never leave a shard with zero replicas — its corpus
            // slice would be unreachable.
            let s_count = node.shards.max(1);
            let mut per_shard = vec![total / s_count; s_count];
            for slot in per_shard.iter_mut().take(total % s_count) {
                *slot += 1;
            }
            if s_count > 1 {
                for slot in per_shard.iter_mut() {
                    *slot = (*slot).max(1);
                }
            }
            instance_counts.insert(node.id, total.max(per_shard.iter().sum()));
            shard_instances.insert(node.id, per_shard);
        }
        AllocationPlan {
            resources,
            instance_counts,
            shard_instances,
            edge_flows: vec![0.0; graph.edges.len()],
            throughput: 0.0,
            pivots: 0,
            // Baselines are placement-blind: no pool split.
            gen_pools: HashMap::new(),
            gen_handoff: HashMap::new(),
        }
    }

    /// Pretty print for the §4.3 "Allocation Plans" discussion.
    pub fn describe(&self, graph: &PipelineGraph) -> String {
        let mut out = format!("plan for '{}': max throughput {:.2} req/s\n", graph.name, self.throughput);
        for node in graph.work_nodes() {
            let inst = self.instances(node.id);
            let mut res = String::new();
            for &(k, _) in &node.resources {
                res.push_str(&format!(" {}={:.1}", k.name(), self.resource(node.id, k)));
            }
            let shards = if node.shards > 1 {
                format!(" shards={:?}", self.shard_instance_counts(node.id))
            } else {
                String::new()
            };
            out.push_str(&format!("  {:<16} instances={inst}{res}{shards}\n", node.name));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::flow::{paper_cluster_budgets, plan_for};
    use crate::spec::apps;

    #[test]
    fn instances_respect_base_floor() {
        let g = apps::corrective_rag();
        let plan = plan_for(&g, 1000, 0);
        let grader = g.node_by_name("grader").unwrap();
        assert!(plan.instances(grader.id) >= grader.base_instances);
        for n in g.work_nodes() {
            assert!(plan.instances(n.id) >= 1, "{} has 0 instances", n.name);
        }
    }

    #[test]
    fn uniform_plan_covers_all_components() {
        let g = apps::adaptive_rag();
        let plan = AllocationPlan::uniform(&g, &paper_cluster_budgets());
        for n in g.work_nodes() {
            assert!(plan.instances(n.id) >= 1, "{}", n.name);
        }
    }

    #[test]
    fn describe_mentions_every_component() {
        let g = apps::self_rag();
        let plan = plan_for(&g, 1000, 1);
        let desc = plan.describe(&g);
        for n in g.work_nodes() {
            assert!(desc.contains(&n.name), "missing {} in:\n{desc}", n.name);
        }
    }
}
