//! The deployment layer's resource allocator (§3.2, Fig. 8).
//!
//! Models the pipeline as a generalized network-flow problem where node
//! capacities are *endogenous*: the solver assigns resource units r_{i,k}
//! to maximize sink flow subject to per-resource budgets, with branch
//! conservation f_{i,j} = p_{i,j} γ_i Σ f_{u,i} capturing conditionals,
//! amplification, and (folded) recursion.

pub mod flow;
pub mod plan;

pub use flow::FlowProblem;
pub use plan::AllocationPlan;
