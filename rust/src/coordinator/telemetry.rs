//! Global telemetry: the controller's single source of truth for
//! per-component execution rates, observed service times, branch
//! traversal frequencies, and in-flight load — the signals that drive
//! routing, scheduling, and reallocation.

use std::collections::HashMap;

use crate::spec::graph::{NodeId, PipelineGraph};
use crate::stats::Ewma;

/// Telemetry aggregated per pipeline node and per edge.
#[derive(Clone, Debug)]
pub struct Telemetry {
    /// Smoothed observed service time per node (seconds).
    service: HashMap<NodeId, Ewma>,
    /// Edge traversal counts (indexed like graph.edges).
    edge_counts: Vec<u64>,
    /// Node exit counts (denominator for branch frequencies).
    exit_counts: HashMap<NodeId, u64>,
    /// Total executions per node.
    executions: HashMap<NodeId, u64>,
    /// Current in-flight requests per node (queued + executing).
    inflight: HashMap<NodeId, i64>,
}

impl Telemetry {
    pub fn new(graph: &PipelineGraph) -> Self {
        Telemetry {
            service: graph.nodes.iter().map(|n| (n.id, Ewma::new(0.08))).collect(),
            edge_counts: vec![0; graph.edges.len()],
            exit_counts: HashMap::new(),
            executions: HashMap::new(),
            inflight: HashMap::new(),
        }
    }

    pub fn on_enqueue(&mut self, node: NodeId) {
        *self.inflight.entry(node).or_insert(0) += 1;
    }

    pub fn on_complete(&mut self, node: NodeId, service_secs: f64) {
        *self.inflight.entry(node).or_insert(0) -= 1;
        *self.executions.entry(node).or_insert(0) += 1;
        self.service.get_mut(&node).map(|e| e.observe(service_secs));
    }

    /// An enqueued item was discarded without executing (cancelled fork
    /// loser popped from a queue): rebalance the in-flight gauge without
    /// polluting the service EWMA or the execution counts.
    pub fn on_cancelled(&mut self, node: NodeId) {
        *self.inflight.entry(node).or_insert(0) -= 1;
    }

    pub fn on_edge(&mut self, edge_idx: usize, from: NodeId) {
        self.edge_counts[edge_idx] += 1;
        *self.exit_counts.entry(from).or_insert(0) += 1;
    }

    pub fn inflight(&self, node: NodeId) -> i64 {
        self.inflight.get(&node).copied().unwrap_or(0)
    }

    pub fn executions(&self, node: NodeId) -> u64 {
        self.executions.get(&node).copied().unwrap_or(0)
    }

    /// Smoothed mean service time; falls back to `prior`.
    pub fn mean_service(&self, node: NodeId, prior: f64) -> f64 {
        self.service.get(&node).map_or(prior, |e| e.get_or(prior))
    }

    /// Observed branch probability for an edge; falls back to the spec
    /// prior until enough exits were seen. Fork edges are structural —
    /// every branch always fires, so their flow fraction is exactly 1
    /// regardless of the counters (the DES books one exit per branch,
    /// which would otherwise read as 1/branches).
    pub fn edge_prob(&self, graph: &PipelineGraph, edge_idx: usize) -> f64 {
        let e = &graph.edges[edge_idx];
        if e.is_fork() {
            return 1.0;
        }
        let exits = self.exit_counts.get(&e.from).copied().unwrap_or(0);
        if exits < 20 {
            e.prob()
        } else {
            self.edge_counts[edge_idx] as f64 / exits as f64
        }
    }

    /// All observed edge probabilities (for re-solving the LP).
    pub fn edge_probs(&self, graph: &PipelineGraph) -> Vec<f64> {
        (0..graph.edges.len()).map(|i| self.edge_prob(graph, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    #[test]
    fn inflight_tracks_enqueue_complete() {
        let g = apps::vanilla_rag();
        let mut t = Telemetry::new(&g);
        let retr = g.node_by_name("retriever").unwrap().id;
        t.on_enqueue(retr);
        t.on_enqueue(retr);
        assert_eq!(t.inflight(retr), 2);
        t.on_complete(retr, 0.1);
        assert_eq!(t.inflight(retr), 1);
        assert_eq!(t.executions(retr), 1);
    }

    #[test]
    fn service_ewma_converges() {
        let g = apps::vanilla_rag();
        let mut t = Telemetry::new(&g);
        let retr = g.node_by_name("retriever").unwrap().id;
        for _ in 0..200 {
            t.on_enqueue(retr);
            t.on_complete(retr, 0.25);
        }
        assert!((t.mean_service(retr, 0.0) - 0.25).abs() < 1e-6);
        // Unobserved node falls back to prior.
        let gen = g.node_by_name("generator").unwrap().id;
        assert_eq!(t.mean_service(gen, 0.5), 0.5);
    }

    #[test]
    fn edge_probs_need_warmup_then_track() {
        let g = apps::corrective_rag();
        let mut t = Telemetry::new(&g);
        let grader = g.node_by_name("grader").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let (gen_edge, _) = g
            .edges
            .iter()
            .enumerate()
            .find(|(_, e)| e.from == grader && e.to == gen)
            .unwrap();
        // Before warmup: prior.
        assert_eq!(t.edge_prob(&g, gen_edge), apps::CRAG_P_RELEVANT);
        // Observe a drifted workload: 90% relevant.
        let (rw_edge, _) = g
            .edges
            .iter()
            .enumerate()
            .find(|(_, e)| e.from == grader && e.to != gen)
            .unwrap();
        for i in 0..100 {
            if i % 10 == 0 {
                t.on_edge(rw_edge, grader);
            } else {
                t.on_edge(gen_edge, grader);
            }
        }
        let p = t.edge_prob(&g, gen_edge);
        assert!((p - 0.9).abs() < 0.01, "p {p}");
    }
}
