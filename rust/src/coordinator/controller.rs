//! The live control plane: deploys a pipeline graph onto worker threads
//! and drives requests through it (the runnable counterpart of the DES).
//!
//! Mirrors §3.3's control/data separation at process scale: the
//! controller thread makes routing decisions and control-flow choices;
//! stage payloads travel inside [`WorkItem`]s directly between workers
//! and the controller's completion channel — the controller inspects
//! state only where the program's control flow requires it (verdicts,
//! classes).
//!
//! All scheduling policy — routing, admission, degradation, predicted
//! slack — is delegated to the same [`crate::sched::ControlPlane`] the
//! DES drives; here its clock is `util::clock::WallClock` and its tick
//! runs from the message loop (`recv_timeout` keeps it firing while
//! idle). This module keeps only the execution mechanics: worker
//! channels, in-flight bookkeeping, and control-flow decoding.
//!
//! The hot loop is allocation- and hash-free on the steady path: workers
//! live in a dense `Vec` indexed by `NodeId`, in-flight requests in a
//! generation-tagged slab keyed by a small recycled index, component
//! names are interned once at deploy, and the routing scratch buffer is
//! reused across dispatches. `CtrlStats` (attached to `RunReport`) makes
//! the loop's own overhead measurable; `benches/perf_live.rs` gates it.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::exec::components::{build_live_shared, spawn_for_kind, EngineMode};
use crate::exec::messages::{Done, RagState, WorkItem};
use crate::exec::worker::WorkerHandle;
use crate::metrics::{CtrlStats, Recorder, RunReport};
use crate::profile::models::RequestFeatures;
use crate::profile::profile_graph_gen_at;
use crate::sched::{ControlPlane, QueueDiscipline, SchedConfig};
use crate::spec::graph::{ComponentKind, ForkGroup, MergePolicy, NodeId, PipelineGraph};
use crate::util::clock::{Clock, WallClock};

use super::router::{InstanceState, RoutingPolicy};

/// Concurrency slots one live worker exposes to the router's load score
/// (also the active/queued split point for its pending count).
const WORKER_SLOTS: usize = 8;

/// Seconds between control-plane ticks (overload ladder reassessment).
const TICK_INTERVAL: f64 = 1.0;

/// Live deployment configuration.
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    pub artifacts: PathBuf,
    /// Stage-engine selection: `Artifacts` (default) runs real XLA
    /// engines from `artifacts`; `Echo` runs the deterministic in-process
    /// engine (no artifacts, no model weights) over the SAME retrieval
    /// index, caches, workers, and control plane — the live hot loop's
    /// bench/test harness.
    pub engine: EngineMode,
    pub corpus_size: usize,
    pub n_topics: usize,
    /// Retrieval index shards (scatter-gather fan-out; 1 = unsharded).
    pub n_shards: usize,
    /// Request-cache knobs (tier capacities, TTL, similarity threshold);
    /// None serves every query through the full embed→retrieve pass.
    pub cache: Option<crate::cache::CacheConfig>,
    /// Generator-side KV prefix cache over retrieved-context segment
    /// chains (`cache::kv_prefix`); None — the default, matching the
    /// DES's `kv_prefix_hit_rate: 0.0` — disables prefix tracking so the
    /// stock deployment is byte-for-byte the pre-disaggregation path.
    pub kv_cache: Option<crate::cache::KvCacheConfig>,
    /// Retrieval index storage mode: `Quantization::SQ8` scans u8 codes
    /// (4× less bandwidth) with exact f32 rescoring; the default
    /// `Quantization::None` keeps the stock deployment byte-for-byte the
    /// pre-quantization f32 path.
    pub quantization: crate::retrieval::Quantization,
    pub seed: u64,
    /// Instances per component (None → the spec's base_instances).
    pub instances: Option<HashMap<String, usize>>,
    /// SLO deadline applied to every request (seconds).
    pub slo: Option<f64>,
    /// Overload-control knobs (admission shedding, degradation ladder,
    /// queue rekey) — `SchedConfig::default()` disables all of them, so
    /// the stock deployment admits everything at full fidelity.
    pub sched: SchedConfig,
    /// Iteration-level (continuous) batching for generator workers: new
    /// requests prefill into a free decode slot between steps and retire
    /// at EOS. **Default on** for the live path; `false` falls back to
    /// run-to-completion static batches. The deploy-time profile prices
    /// the generator with the matching `profile::models::DecodeCostModel`
    /// mode either way, so admission-slack predictions and priors agree
    /// with what the workers actually do.
    pub continuous_batching: bool,
}

impl ControllerConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        ControllerConfig {
            artifacts,
            engine: EngineMode::Artifacts,
            corpus_size: 512,
            n_topics: 8,
            n_shards: 4,
            cache: Some(crate::cache::CacheConfig::default()),
            kv_cache: None,
            quantization: crate::retrieval::Quantization::None,
            seed: 0,
            instances: None,
            slo: None,
            sched: SchedConfig::default(),
            continuous_batching: true,
        }
    }

    /// Echo-engine deployment: no artifacts required, deterministic
    /// outputs, real retrieval/cache/scheduling path. This is what
    /// `benches/perf_live.rs` and the artifact-free live tests deploy.
    pub fn echo(seed: u64) -> Self {
        let mut cfg = ControllerConfig::quick(PathBuf::new());
        cfg.engine = EngineMode::Echo;
        cfg.seed = seed;
        cfg
    }
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct LiveResponse {
    pub req: u64,
    pub answer: Vec<u8>,
    pub latency_secs: f64,
    pub hops: usize,
    pub error: Option<String>,
}

enum Msg {
    Submit { query: Vec<u8>, resp: Sender<LiveResponse> },
    Done(Done),
    Report(Sender<RunReport>),
    Shutdown,
}

/// Client handle to a deployed pipeline.
pub struct ServingHandle {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// A cheap, cloneable submission handle (`ServingHandle::client`): load
/// generators hand one to each driver thread while the orchestrator
/// keeps the `ServingHandle` for `report`/`shutdown`.
#[derive(Clone)]
pub struct LiveClient {
    tx: Sender<Msg>,
}

impl LiveClient {
    /// Submit a query; the response arrives on the returned channel.
    pub fn submit(&self, query: &[u8]) -> Receiver<LiveResponse> {
        let (resp_tx, resp_rx) = channel();
        let _ = self.tx.send(Msg::Submit { query: query.to_vec(), resp: resp_tx });
        resp_rx
    }
}

impl ServingHandle {
    /// Submit a query; the response arrives on the returned channel.
    pub fn submit(&self, query: &[u8]) -> Receiver<LiveResponse> {
        let (resp_tx, resp_rx) = channel();
        let _ = self.tx.send(Msg::Submit { query: query.to_vec(), resp: resp_tx });
        resp_rx
    }

    /// A cloneable submitter for multi-threaded load drivers.
    pub fn client(&self) -> LiveClient {
        LiveClient { tx: self.tx.clone() }
    }

    /// Fetch the run metrics so far.
    pub fn report(&self) -> RunReport {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Report(tx));
        rx.recv().expect("controller alive")
    }

    /// Graceful shutdown (waits for the controller thread).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

struct InflightReq {
    /// User-facing sequential request id (`LiveResponse::req`); the
    /// wire-level key workers echo back is the slab key, which recycles.
    ext_id: u64,
    resp: Sender<LiveResponse>,
    started: Instant,
    deadline: Option<f64>,
    hops: usize,
    current: NodeId,
    /// Approximate request features feeding the slack predictor (live
    /// queries carry no token counts; prompt bytes stand in).
    features: RequestFeatures,
    /// Branch-id allocator for fork subtasks (0 = the trunk).
    next_branch: u32,
    /// Shared join cells, one per in-flight fork, keyed by the join
    /// node: branch completions accumulate here until the barrier
    /// releases; the merged state then dispatches the join exactly once.
    /// A Vec, not a map — real programs hold at most a couple of live
    /// forks, and a linear scan beats hashing at that size.
    joins: Vec<(NodeId, LiveJoin)>,
}

/// Barrier state of one in-flight fork on the live path.
struct LiveJoin {
    /// Branch ids belonging to THIS fork traversal. Cells are keyed by
    /// join node and recursion may wrap a fork (loop re-entering it), so
    /// a stale loser from a previous traversal must not be mistaken for
    /// a member of the fresh barrier — membership is explicit.
    branches: Vec<u32>,
    /// Arrivals that release the barrier.
    need: usize,
    merge: MergePolicy,
    /// Completed branch states, in arrival order.
    states: Vec<RagState>,
    /// Wall-clock arrival stamps (join-wait accounting).
    arrivals: Vec<Instant>,
    /// Barrier already released: late FirstK losers are dropped here —
    /// their `Done`s merge nowhere and route nowhere.
    fired: bool,
}

impl LiveJoin {
    fn new(fg: &ForkGroup) -> LiveJoin {
        LiveJoin {
            branches: Vec::new(),
            need: fg.need,
            merge: fg.merge,
            states: Vec::new(),
            arrivals: Vec::new(),
            fired: false,
        }
    }
}

/// Install `cell` as the live barrier for `node`, replacing any stale
/// cell left by a previous traversal of the same fork (loop wrap) — the
/// replace-not-append semantics the old `HashMap::insert` had.
fn set_join(joins: &mut Vec<(NodeId, LiveJoin)>, node: NodeId, cell: LiveJoin) {
    if let Some(slot) = joins.iter_mut().find(|(n, _)| *n == node) {
        slot.1 = cell;
    } else {
        joins.push((node, cell));
    }
}

/// In-flight request table: a slab keyed by `(generation << 32) | slot`.
///
/// The slot index recycles (steady state touches the same few cache
/// lines instead of growing a hash table), while the generation tag makes
/// recycled keys unambiguous: a stale FirstK loser carrying a retired
/// key misses the lookup instead of corrupting the slot's new tenant.
struct InflightSlab {
    slots: Vec<SlabSlot>,
    free: Vec<u32>,
    live: usize,
}

struct SlabSlot {
    generation: u32,
    req: Option<InflightReq>,
}

impl InflightSlab {
    fn new() -> InflightSlab {
        InflightSlab { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    fn insert(&mut self, req: InflightReq) -> u64 {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(SlabSlot { generation: 0, req: None });
                (self.slots.len() - 1) as u32
            }
        };
        let cell = &mut self.slots[slot as usize];
        debug_assert!(cell.req.is_none(), "free list handed out an occupied slot");
        cell.req = Some(req);
        self.live += 1;
        ((cell.generation as u64) << 32) | slot as u64
    }

    fn get_mut(&mut self, key: u64) -> Option<&mut InflightReq> {
        let slot = (key & 0xffff_ffff) as usize;
        let generation = (key >> 32) as u32;
        let cell = self.slots.get_mut(slot)?;
        if cell.generation != generation {
            return None;
        }
        cell.req.as_mut()
    }

    fn remove(&mut self, key: u64) -> Option<InflightReq> {
        let slot = (key & 0xffff_ffff) as usize;
        let generation = (key >> 32) as u32;
        let cell = self.slots.get_mut(slot)?;
        if cell.generation != generation {
            return None;
        }
        let req = cell.req.take()?;
        cell.generation = cell.generation.wrapping_add(1);
        self.free.push(slot as u32);
        self.live -= 1;
        Some(req)
    }
}

/// Deploy a pipeline graph as live workers + a controller thread.
pub fn deploy(graph: PipelineGraph, cfg: ControllerConfig) -> Result<ServingHandle> {
    let mut shared = build_live_shared(
        cfg.artifacts.clone(),
        cfg.corpus_size,
        cfg.n_topics,
        cfg.n_shards,
        cfg.cache,
        cfg.kv_cache,
        cfg.quantization,
        cfg.seed,
        cfg.engine,
    )
    .context("building live shared state (corpus/index)")?;
    shared.continuous_batching = cfg.continuous_batching;
    let shared = Arc::new(shared);

    // Spawn workers per component (each carries its node's degrade knob
    // so it can shed fidelity when the shared overload cell says so).
    // Dense by NodeId: the dispatch path indexes, never hashes.
    let mut workers: Vec<Vec<WorkerHandle>> = (0..graph.nodes.len()).map(|_| Vec::new()).collect();
    for node in graph.work_nodes() {
        let n = cfg
            .instances
            .as_ref()
            .and_then(|m| m.get(&node.name).copied())
            .unwrap_or_else(|| node.base_instances.max(1));
        workers[node.id.0] = (0..n)
            .map(|i| {
                spawn_for_kind(
                    format!("{}-{i}", node.name),
                    &node.kind,
                    node.degrade,
                    shared.clone(),
                )
            })
            .collect();
    }

    let (tx, rx) = channel::<Msg>();
    // Bridge worker completions into the controller's single channel.
    let (done_tx, done_rx) = channel::<Done>();
    {
        let tx = tx.clone();
        std::thread::spawn(move || {
            for d in done_rx {
                if tx.send(Msg::Done(d)).is_err() {
                    break;
                }
            }
        });
    }

    // The shared control plane: same policy object the DES drives, wired
    // to the workers' overload cell + counters, ticked by the wall clock.
    // The generator prior is priced under the batching mode — and at the
    // decode occupancy — the workers actually run (the engine batches at
    // its largest compiled bucket, which matches WORKER_SLOTS), so the
    // slack predictor's seed (and with it admission control) sees real
    // batched-decode economics, not the per-instance DES slot count.
    let gen_mode = if cfg.continuous_batching {
        crate::profile::GenBatching::Continuous
    } else {
        crate::profile::GenBatching::Static
    };
    let prior = profile_graph_gen_at(&graph, 200, cfg.seed ^ 0x5CED, gen_mode, WORKER_SLOTS);
    let plane = ControlPlane::new(
        &graph,
        &prior.mean_service,
        RoutingPolicy::LoadStateAware,
        QueueDiscipline::LeastSlack,
        cfg.sched,
        10.0,
    )
    .share(shared.degrade.clone(), shared.sched_counters.clone());

    let slo = cfg.slo;
    let cache = shared.cache.clone();
    let kv_cache = shared.kv_cache.clone();
    let k_docs = shared.k_docs;
    let max_new_tokens = shared.max_new_tokens;
    let join = std::thread::Builder::new()
        .name("harmonia-controller".into())
        .spawn(move || {
            controller_loop(ControllerLoop {
                graph,
                workers,
                rx,
                done_tx,
                slo,
                cache,
                kv_cache,
                plane,
                k_docs,
                max_new_tokens,
            })
        })
        .expect("spawn controller");

    Ok(ServingHandle { tx, join: Some(join) })
}

/// Everything the controller thread owns.
struct ControllerLoop {
    graph: PipelineGraph,
    workers: Vec<Vec<WorkerHandle>>,
    rx: Receiver<Msg>,
    done_tx: Sender<Done>,
    slo: Option<f64>,
    cache: Option<Arc<crate::cache::QueryCache>>,
    kv_cache: Option<Arc<crate::cache::KvPrefixCache>>,
    plane: ControlPlane,
    k_docs: usize,
    max_new_tokens: usize,
}

/// One hop onto a worker: snapshot the pool's load into the reusable
/// scratch buffer, route, hand over the (zero-copy) state. Every input
/// is a dense index or a preresolved reference — no hash probes, no
/// String clones, no per-dispatch Vec allocation.
#[allow(clippy::too_many_arguments)]
fn dispatch_item(
    req: u64,
    node: NodeId,
    branch: u32,
    state: RagState,
    plane: &mut ControlPlane,
    workers: &[Vec<WorkerHandle>],
    stateful: &[bool],
    scratch: &mut Vec<InstanceState>,
    done_tx: &Arc<Sender<Done>>,
    ctrl: &mut CtrlStats,
) {
    let t0 = Instant::now();
    let pool = &workers[node.0];
    scratch.clear();
    for w in pool {
        let pending = w.pending();
        scratch.push(InstanceState {
            active: pending.min(WORKER_SLOTS),
            queued: pending.saturating_sub(WORKER_SLOTS),
            slots: WORKER_SLOTS,
            expected_reentries: 0.0,
            up: w.is_up(),
        });
    }
    let pick = plane.route(req, node, stateful[node.0], scratch);
    let item = WorkItem::for_branch(req, node, branch, state, done_tx.clone());
    let _ = pool[pick].submit(item);
    ctrl.dispatches += 1;
    ctrl.dispatch_secs += t0.elapsed().as_secs_f64();
}

fn controller_loop(lp: ControllerLoop) {
    let ControllerLoop {
        graph,
        workers,
        rx,
        done_tx,
        slo,
        cache,
        kv_cache,
        mut plane,
        k_docs,
        max_new_tokens,
    } = lp;
    let done_tx = Arc::new(done_tx);
    let mut recorder = Recorder::new();
    let mut inflight = InflightSlab::new();
    let mut next_ext: u64 = 0;
    let mut ctrl = CtrlStats::default();
    let clock = WallClock::new();
    let mut last_tick = 0.0f64;
    let mut rng = crate::util::rng::Rng::new(0x11FE);

    let total_slots: usize = workers.iter().map(|v| v.len() * WORKER_SLOTS).sum();
    // Dense per-node tables, interned once: the completion path reads
    // `node_names[id.0]` instead of cloning a String per Done, and the
    // dispatch path reads `stateful[id.0]` instead of probing a map.
    let mut stateful = vec![false; graph.nodes.len()];
    let mut node_names = vec![String::new(); graph.nodes.len()];
    for n in &graph.nodes {
        stateful[n.id.0] = n.stateful;
        node_names[n.id.0] = n.name.clone();
    }
    // Dense fork index from the spec compiler (branch entries + join +
    // barrier policy per fork node); the controller dispatches ALL fork
    // successors at once and merges their `Done`s at the join cell.
    let fork_map = graph.analyze().fork_map;
    // Routing scratch, reused across every dispatch.
    let mut scratch: Vec<InstanceState> = Vec::new();

    // Busy/idle split: `mark` is the instant the last blocking wait
    // ended; everything between it and the next wait is processing time.
    let mut mark = Instant::now();
    loop {
        // The unified control tick, wall-clock driven. Live queues are
        // worker channels (FIFO by construction), so the tick's rekey
        // outcome has nothing to reorder here; reallocation needs worker
        // spawn/drain and stays sim-only for now — hence `realloc: None`.
        let now = clock.now();
        if now - last_tick >= TICK_INTERVAL {
            last_tick = now;
            let pending: usize = workers.iter().flatten().map(|w| w.pending()).sum();
            let util = pending as f64 / total_slots.max(1) as f64;
            let _ = plane.tick(now, util, None);
        }

        let wait_start = Instant::now();
        ctrl.busy_secs += wait_start.duration_since(mark).as_secs_f64();
        let res = rx.recv_timeout(Duration::from_millis(200));
        mark = Instant::now();
        ctrl.idle_secs += mark.duration_since(wait_start).as_secs_f64();
        let msg = match res {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        match msg {
            Msg::Submit { query, resp } => {
                let ext = next_ext;
                next_ext += 1;
                let now = clock.now();
                recorder.on_arrival(now);
                let entry = graph
                    .successors(graph.source)
                    .next()
                    .expect("source successor")
                    .to;
                // Live features: prompt bytes stand in for token counts;
                // retrieval volume and generation budget come from the
                // deployment, so the slack regressors see real signals.
                let features = RequestFeatures {
                    prompt_len: query.len().clamp(4, 127),
                    gen_len: max_new_tokens,
                    k_docs,
                    complexity: 1,
                };
                if plane.admission_enabled() {
                    let pool = &workers[entry.0];
                    // Queued work only (pending minus the slots actively
                    // executing), matching the DES's node_load semantics
                    // so one AdmissionConfig means the same thresholds on
                    // both backends.
                    let queued: usize = pool
                        .iter()
                        .map(|w| w.pending().saturating_sub(WORKER_SLOTS))
                        .sum();
                    let capacity = pool.len() * WORKER_SLOTS;
                    let deadline = slo.map(|s| now + s);
                    let decision =
                        plane.admit(entry, &features, now, deadline, queued, capacity);
                    if !decision.admitted() {
                        recorder.on_shed();
                        let _ = resp.send(LiveResponse {
                            req: ext,
                            answer: Vec::new(),
                            latency_secs: 0.0,
                            hops: 0,
                            error: Some(format!("shed by admission control: {decision:?}")),
                        });
                        continue;
                    }
                }
                let state = RagState::new(&query);
                let req = inflight.insert(InflightReq {
                    ext_id: ext,
                    resp,
                    started: Instant::now(),
                    deadline: slo,
                    hops: 0,
                    current: entry,
                    features,
                    next_branch: 0,
                    joins: Vec::new(),
                });
                // A fork at the pipeline entry fans out immediately
                // (hybrid retrieval: dense ∥ web from the first hop).
                // Branch states are Arc clones of the trunk — the
                // fan-out is pointer bumps, not byte copies.
                if let Some(fg) = fork_map[graph.source.0].as_ref() {
                    let fl = inflight.get_mut(req).expect("just inserted");
                    let mut cell = LiveJoin::new(fg);
                    let mut spawned = Vec::with_capacity(fg.targets.len());
                    for &target in &fg.targets {
                        fl.next_branch += 1;
                        cell.branches.push(fl.next_branch);
                        spawned.push((fl.next_branch, target));
                    }
                    set_join(&mut fl.joins, fg.join, cell);
                    for (b, target) in spawned {
                        dispatch_item(
                            req,
                            target,
                            b,
                            state.clone(),
                            &mut plane,
                            &workers,
                            &stateful,
                            &mut scratch,
                            &done_tx,
                            &mut ctrl,
                        );
                    }
                } else {
                    dispatch_item(
                        req,
                        entry,
                        0,
                        state,
                        &mut plane,
                        &workers,
                        &stateful,
                        &mut scratch,
                        &done_tx,
                        &mut ctrl,
                    );
                }
            }
            Msg::Done(d) => {
                ctrl.completions += 1;
                // A stale key (recycled slot, bumped generation) is a
                // FirstK loser whose request already finished: drop it.
                let Some(fl) = inflight.get_mut(d.req) else { continue };
                fl.hops += 1;
                recorder.on_execution(&node_names[d.node.0], d.service_secs, d.queue_secs);
                let features = fl.features;
                if let Some(err) = d.error {
                    let fl = inflight.remove(d.req).unwrap();
                    let _ = fl.resp.send(LiveResponse {
                        req: fl.ext_id,
                        answer: Vec::new(),
                        latency_secs: fl.started.elapsed().as_secs_f64(),
                        hops: fl.hops,
                        error: Some(err),
                    });
                    plane.release(d.req);
                    continue;
                }
                // Successful completions only: an errored item reports
                // service_secs ≈ 0 (worker init failure), and feeding that
                // into the slack regressors would collapse predictions to
                // zero exactly when admission control needs them.
                plane.on_complete(d.node, d.service_secs);
                plane.observe_service(d.node, &features, d.service_secs);
                // Parallel fan-out: a fork node's completion dispatches
                // EVERY branch at once, each tagged with its own branch
                // id and reporting to a fresh join cell. Re-dispatch is
                // Arc clones — pointer bumps, not byte copies.
                if let Some(fg) = fork_map[d.node.0].as_ref() {
                    let mut cell = LiveJoin::new(fg);
                    let mut spawned = Vec::with_capacity(fg.targets.len());
                    for &target in &fg.targets {
                        fl.next_branch += 1;
                        cell.branches.push(fl.next_branch);
                        spawned.push((fl.next_branch, target));
                    }
                    set_join(&mut fl.joins, fg.join, cell);
                    for (b, target) in spawned {
                        dispatch_item(
                            d.req,
                            target,
                            b,
                            d.state.clone(),
                            &mut plane,
                            &workers,
                            &stateful,
                            &mut scratch,
                            &done_tx,
                            &mut ctrl,
                        );
                    }
                    continue;
                }
                let next = decide_next(&graph, d.node, &d.state, &mut rng);
                // A branch completion bound for a join node reports to
                // the barrier instead of dispatching the join directly.
                if next != graph.sink && graph.node(next).join.is_some() {
                    if let Some((_, cell)) = fl.joins.iter_mut().find(|(n, _)| *n == next) {
                        if cell.branches.contains(&d.branch) {
                            if cell.fired {
                                // Late FirstK loser: state dropped; its
                                // worker slot was already released by
                                // the Done itself.
                                continue;
                            }
                            cell.states.push(d.state);
                            cell.arrivals.push(Instant::now());
                            if cell.states.len() < cell.need {
                                continue;
                            }
                            cell.fired = true;
                            // Losers still in flight retire harmlessly
                            // at the `fired` gate above — queue and
                            // engine state stay consistent.
                            let merged =
                                RagState::merge(cell.merge, std::mem::take(&mut cell.states));
                            let release = *cell.arrivals.last().expect("at least one arrival");
                            let stall: f64 = cell.arrivals[..cell.arrivals.len() - 1]
                                .iter()
                                .map(|t| release.duration_since(*t).as_secs_f64())
                                .sum();
                            recorder.on_join_wait(&node_names[next.0], stall);
                            fl.current = next;
                            dispatch_item(
                                d.req,
                                next,
                                0,
                                merged,
                                &mut plane,
                                &workers,
                                &stateful,
                                &mut scratch,
                                &done_tx,
                                &mut ctrl,
                            );
                            continue;
                        }
                        if d.branch != 0 {
                            // Stale loser from a PREVIOUS traversal of
                            // this fork (recursion wrapped a FirstK
                            // race): it must neither merge into nor
                            // release the fresh barrier.
                            continue;
                        }
                        // Trunk arrival (no branch context): not a
                        // barrier member — fall through to a normal hop.
                    }
                }
                if next == graph.sink {
                    let fl = inflight.remove(d.req).unwrap();
                    let latency = fl.started.elapsed().as_secs_f64();
                    let now = clock.now();
                    recorder.on_completion(now - latency, now, fl.deadline.map(|s| now - latency + s));
                    let _ = fl.resp.send(LiveResponse {
                        req: fl.ext_id,
                        answer: d.state.into_answer(),
                        latency_secs: latency,
                        hops: fl.hops,
                        error: None,
                    });
                    plane.release(d.req);
                } else {
                    fl.current = next;
                    dispatch_item(
                        d.req,
                        next,
                        d.branch,
                        d.state,
                        &mut plane,
                        &workers,
                        &stateful,
                        &mut scratch,
                        &done_tx,
                        &mut ctrl,
                    );
                }
            }
            Msg::Report(tx) => {
                if let Some(c) = &cache {
                    recorder.set_cache(c.snapshot());
                }
                if let Some(kc) = &kv_cache {
                    recorder.set_kv_prefix(kc.snapshot());
                }
                if plane.cfg.enabled() {
                    recorder.set_sched(plane.counters.snapshot());
                }
                recorder.set_ctrl(ctrl);
                let _ = tx.send(recorder.report());
            }
            Msg::Shutdown => break,
        }
    }
    for pool in workers {
        for w in pool {
            w.shutdown();
        }
    }
}

/// Control-flow decision: maps (node kind, request state) to the next
/// node — the live counterpart of the program's `if`/`while` structure
/// (Fig. 7). Falls back to probability-weighted choice for custom nodes.
pub fn decide_next(
    graph: &PipelineGraph,
    node: NodeId,
    state: &RagState,
    rng: &mut crate::util::rng::Rng,
) -> NodeId {
    let succ: Vec<_> = graph.successors(node).collect();
    debug_assert!(!succ.is_empty());
    if succ.len() == 1 {
        return succ[0].to;
    }
    let kind = &graph.node(node).kind;
    match kind {
        ComponentKind::Grader => {
            // Relevant context → straight to a generator; else rewrite.
            let want_generator = state.verdict.unwrap_or(true);
            pick_by(graph, &succ, |k| {
                if want_generator {
                    matches!(k, ComponentKind::Generator)
                } else {
                    !matches!(k, ComponentKind::Generator)
                }
            })
        }
        ComponentKind::Critic => {
            // Accept (or iteration budget exhausted) → sink; else loop.
            let accept = state.verdict.unwrap_or(true) || state.iteration >= 2;
            if accept {
                succ.iter()
                    .find(|e| e.to == graph.sink)
                    .map(|e| e.to)
                    .unwrap_or(succ[0].to)
            } else {
                succ.iter()
                    .find(|e| e.to != graph.sink)
                    .map(|e| e.to)
                    .unwrap_or(succ[0].to)
            }
        }
        ComponentKind::Classifier => {
            let class = state.class.unwrap_or(1);
            match class {
                0 => pick_by(graph, &succ, |k| matches!(k, ComponentKind::Generator)),
                2 => succ
                    .iter()
                    .find(|e| graph.node(e.to).name.starts_with("iter"))
                    .map(|e| e.to)
                    .unwrap_or_else(|| {
                        pick_by(graph, &succ, |k| matches!(k, ComponentKind::Retriever))
                    }),
                _ => succ
                    .iter()
                    .find(|e| {
                        matches!(graph.node(e.to).kind, ComponentKind::Retriever)
                            && !graph.node(e.to).name.starts_with("iter")
                    })
                    .map(|e| e.to)
                    .unwrap_or(succ[0].to),
            }
        }
        _ => {
            // Probability-weighted (spec priors).
            let weights: Vec<f64> = succ.iter().map(|e| e.prob()).collect();
            succ[rng.weighted(&weights)].to
        }
    }
}

fn pick_by(
    graph: &PipelineGraph,
    succ: &[&crate::spec::graph::EdgeSpec],
    pred: impl Fn(&ComponentKind) -> bool,
) -> NodeId {
    succ.iter()
        .find(|e| pred(&graph.node(e.to).kind))
        .map(|e| e.to)
        .unwrap_or(succ[0].to)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;
    use crate::util::rng::Rng;

    #[test]
    fn decide_next_linear_pipeline() {
        let g = apps::vanilla_rag();
        let mut rng = Rng::new(0);
        let retr = g.node_by_name("retriever").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let s = RagState::new(b"q");
        assert_eq!(decide_next(&g, retr, &s, &mut rng), gen);
        assert_eq!(decide_next(&g, gen, &s, &mut rng), g.sink);
    }

    #[test]
    fn decide_next_crag_branches_on_verdict() {
        let g = apps::corrective_rag();
        let mut rng = Rng::new(0);
        let grader = g.node_by_name("grader").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let rewriter = g.node_by_name("rewriter").unwrap().id;
        let mut s = RagState::new(b"q");
        s.verdict = Some(true);
        assert_eq!(decide_next(&g, grader, &s, &mut rng), gen);
        s.verdict = Some(false);
        assert_eq!(decide_next(&g, grader, &s, &mut rng), rewriter);
    }

    #[test]
    fn decide_next_srag_loop_bounded() {
        let g = apps::self_rag();
        let mut rng = Rng::new(0);
        let critic = g.node_by_name("critic").unwrap().id;
        let rewriter = g.node_by_name("rewriter").unwrap().id;
        let mut s = RagState::new(b"q");
        s.verdict = Some(false);
        s.iteration = 0;
        assert_eq!(decide_next(&g, critic, &s, &mut rng), rewriter);
        // Budget exhausted: must exit even on reject.
        s.iteration = 2;
        assert_eq!(decide_next(&g, critic, &s, &mut rng), g.sink);
    }

    #[test]
    fn decide_next_arag_routes_by_class() {
        let g = apps::adaptive_rag();
        let mut rng = Rng::new(0);
        let cls = g.node_by_name("classifier").unwrap().id;
        let mut s = RagState::new(b"q");
        s.class = Some(0);
        assert_eq!(
            decide_next(&g, cls, &s, &mut rng),
            g.node_by_name("generator").unwrap().id
        );
        s.class = Some(1);
        assert_eq!(
            decide_next(&g, cls, &s, &mut rng),
            g.node_by_name("retriever").unwrap().id
        );
        s.class = Some(2);
        assert_eq!(
            decide_next(&g, cls, &s, &mut rng),
            g.node_by_name("iter_retriever").unwrap().id
        );
    }

    fn dummy_req(ext: u64) -> InflightReq {
        let (tx, _rx) = channel();
        InflightReq {
            ext_id: ext,
            resp: tx,
            started: Instant::now(),
            deadline: None,
            hops: 0,
            current: NodeId(0),
            features: RequestFeatures {
                prompt_len: 4,
                gen_len: 8,
                k_docs: 4,
                complexity: 1,
            },
            next_branch: 0,
            joins: Vec::new(),
        }
    }

    #[test]
    fn slab_recycles_slots_and_rejects_stale_keys() {
        let mut slab = InflightSlab::new();
        let k0 = slab.insert(dummy_req(100));
        let k1 = slab.insert(dummy_req(101));
        assert_eq!(k0 & 0xffff_ffff, 0, "first insert takes slot 0");
        assert_eq!(k1 & 0xffff_ffff, 1, "second insert takes slot 1");
        assert_eq!(slab.get_mut(k0).unwrap().ext_id, 100);

        let removed = slab.remove(k0).unwrap();
        assert_eq!(removed.ext_id, 100);
        // Stale key: same slot, retired generation — must miss, exactly
        // like a late FirstK loser carrying a finished request's key.
        assert!(slab.get_mut(k0).is_none());
        assert!(slab.remove(k0).is_none());

        // The slot recycles with a bumped generation: the new key is
        // distinct from every key the slot handed out before.
        let k2 = slab.insert(dummy_req(102));
        assert_eq!(k2 & 0xffff_ffff, 0, "freed slot 0 is reused");
        assert_ne!(k2, k0, "generation tag disambiguates the recycled slot");
        assert!(slab.get_mut(k0).is_none(), "old key still misses");
        assert_eq!(slab.get_mut(k2).unwrap().ext_id, 102);
        assert_eq!(slab.live, 2);
    }

    #[test]
    fn set_join_replaces_cell_for_same_node() {
        let fg = ForkGroup {
            fork: NodeId(0),
            join: NodeId(3),
            targets: vec![NodeId(1), NodeId(2)],
            edges: vec![0, 1],
            policy: crate::spec::graph::JoinPolicy::All,
            merge: MergePolicy::Union,
            need: 2,
        };
        let mut joins: Vec<(NodeId, LiveJoin)> = Vec::new();
        let mut first = LiveJoin::new(&fg);
        first.branches.push(1);
        first.branches.push(2);
        set_join(&mut joins, fg.join, first);
        assert_eq!(joins.len(), 1);
        // A loop wrapping the fork re-arms the barrier: the fresh cell
        // REPLACES the stale one (old HashMap::insert semantics), so a
        // loser from the previous traversal can't satisfy it.
        let mut second = LiveJoin::new(&fg);
        second.branches.push(3);
        second.branches.push(4);
        set_join(&mut joins, fg.join, second);
        assert_eq!(joins.len(), 1, "same join node replaces, not appends");
        assert_eq!(joins[0].1.branches, vec![3, 4]);
    }
}
