//! The live control plane: deploys a pipeline graph onto worker threads
//! and drives requests through it (the runnable counterpart of the DES).
//!
//! Mirrors §3.3's control/data separation at process scale: the
//! controller thread makes routing decisions and control-flow choices;
//! stage payloads travel inside [`WorkItem`]s directly between workers
//! and the controller's completion channel — the controller inspects
//! state only where the program's control flow requires it (verdicts,
//! classes).
//!
//! All scheduling policy — routing, admission, degradation, predicted
//! slack — is delegated to the same [`crate::sched::ControlPlane`] the
//! DES drives; here its clock is `util::clock::WallClock` and its tick
//! runs from the message loop (`recv_timeout` keeps it firing while
//! idle). This module keeps only the execution mechanics: worker
//! channels, in-flight bookkeeping, and control-flow decoding.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::exec::components::{build_live_shared, spawn_for_kind};
use crate::exec::messages::{Done, RagState, WorkItem};
use crate::exec::worker::WorkerHandle;
use crate::metrics::{Recorder, RunReport};
use crate::profile::models::RequestFeatures;
use crate::profile::profile_graph_gen_at;
use crate::sched::{ControlPlane, QueueDiscipline, SchedConfig};
use crate::spec::graph::{ComponentKind, ForkGroup, MergePolicy, NodeId, PipelineGraph};
use crate::util::clock::{Clock, WallClock};

use super::router::{InstanceState, RoutingPolicy};

/// Concurrency slots one live worker exposes to the router's load score
/// (also the active/queued split point for its pending count).
const WORKER_SLOTS: usize = 8;

/// Seconds between control-plane ticks (overload ladder reassessment).
const TICK_INTERVAL: f64 = 1.0;

/// Live deployment configuration.
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    pub artifacts: PathBuf,
    pub corpus_size: usize,
    pub n_topics: usize,
    /// Retrieval index shards (scatter-gather fan-out; 1 = unsharded).
    pub n_shards: usize,
    /// Request-cache knobs (tier capacities, TTL, similarity threshold);
    /// None serves every query through the full embed→retrieve pass.
    pub cache: Option<crate::cache::CacheConfig>,
    /// Generator-side KV prefix cache over retrieved-context segment
    /// chains (`cache::kv_prefix`); None — the default, matching the
    /// DES's `kv_prefix_hit_rate: 0.0` — disables prefix tracking so the
    /// stock deployment is byte-for-byte the pre-disaggregation path.
    pub kv_cache: Option<crate::cache::KvCacheConfig>,
    /// Retrieval index storage mode: `Quantization::SQ8` scans u8 codes
    /// (4× less bandwidth) with exact f32 rescoring; the default
    /// `Quantization::None` keeps the stock deployment byte-for-byte the
    /// pre-quantization f32 path.
    pub quantization: crate::retrieval::Quantization,
    pub seed: u64,
    /// Instances per component (None → the spec's base_instances).
    pub instances: Option<HashMap<String, usize>>,
    /// SLO deadline applied to every request (seconds).
    pub slo: Option<f64>,
    /// Overload-control knobs (admission shedding, degradation ladder,
    /// queue rekey) — `SchedConfig::default()` disables all of them, so
    /// the stock deployment admits everything at full fidelity.
    pub sched: SchedConfig,
    /// Iteration-level (continuous) batching for generator workers: new
    /// requests prefill into a free decode slot between steps and retire
    /// at EOS. **Default on** for the live path; `false` falls back to
    /// run-to-completion static batches. The deploy-time profile prices
    /// the generator with the matching `profile::models::DecodeCostModel`
    /// mode either way, so admission-slack predictions and priors agree
    /// with what the workers actually do.
    pub continuous_batching: bool,
}

impl ControllerConfig {
    pub fn quick(artifacts: PathBuf) -> Self {
        ControllerConfig {
            artifacts,
            corpus_size: 512,
            n_topics: 8,
            n_shards: 4,
            cache: Some(crate::cache::CacheConfig::default()),
            kv_cache: None,
            quantization: crate::retrieval::Quantization::None,
            seed: 0,
            instances: None,
            slo: None,
            sched: SchedConfig::default(),
            continuous_batching: true,
        }
    }
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct LiveResponse {
    pub req: u64,
    pub answer: Vec<u8>,
    pub latency_secs: f64,
    pub hops: usize,
    pub error: Option<String>,
}

enum Msg {
    Submit { query: Vec<u8>, resp: Sender<LiveResponse> },
    Done(Done),
    Report(Sender<RunReport>),
    Shutdown,
}

/// Client handle to a deployed pipeline.
pub struct ServingHandle {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServingHandle {
    /// Submit a query; the response arrives on the returned channel.
    pub fn submit(&self, query: &[u8]) -> Receiver<LiveResponse> {
        let (resp_tx, resp_rx) = channel();
        let _ = self.tx.send(Msg::Submit { query: query.to_vec(), resp: resp_tx });
        resp_rx
    }

    /// Fetch the run metrics so far.
    pub fn report(&self) -> RunReport {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Report(tx));
        rx.recv().expect("controller alive")
    }

    /// Graceful shutdown (waits for the controller thread).
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

struct InflightReq {
    resp: Sender<LiveResponse>,
    started: Instant,
    deadline: Option<f64>,
    hops: usize,
    current: NodeId,
    /// Approximate request features feeding the slack predictor (live
    /// queries carry no token counts; prompt bytes stand in).
    features: RequestFeatures,
    /// Branch-id allocator for fork subtasks (0 = the trunk).
    next_branch: u32,
    /// Shared join cells, one per in-flight fork, keyed by the join
    /// node: branch completions accumulate here until the barrier
    /// releases; the merged state then dispatches the join exactly once.
    joins: HashMap<NodeId, LiveJoin>,
}

/// Barrier state of one in-flight fork on the live path.
struct LiveJoin {
    /// Branch ids belonging to THIS fork traversal. Cells are keyed by
    /// join node and recursion may wrap a fork (loop re-entering it), so
    /// a stale loser from a previous traversal must not be mistaken for
    /// a member of the fresh barrier — membership is explicit.
    branches: std::collections::HashSet<u32>,
    /// Arrivals that release the barrier.
    need: usize,
    merge: MergePolicy,
    /// Completed branch states, in arrival order.
    states: Vec<RagState>,
    /// Wall-clock arrival stamps (join-wait accounting).
    arrivals: Vec<Instant>,
    /// Barrier already released: late FirstK losers are dropped here —
    /// their `Done`s merge nowhere and route nowhere.
    fired: bool,
}

impl LiveJoin {
    fn new(fg: &ForkGroup) -> LiveJoin {
        LiveJoin {
            branches: std::collections::HashSet::new(),
            need: fg.need,
            merge: fg.merge,
            states: Vec::new(),
            arrivals: Vec::new(),
            fired: false,
        }
    }
}

/// Deploy a pipeline graph as live workers + a controller thread.
pub fn deploy(graph: PipelineGraph, cfg: ControllerConfig) -> Result<ServingHandle> {
    let mut shared = build_live_shared(
        cfg.artifacts.clone(),
        cfg.corpus_size,
        cfg.n_topics,
        cfg.n_shards,
        cfg.cache,
        cfg.kv_cache,
        cfg.quantization,
        cfg.seed,
    )
    .context("building live shared state (corpus/index)")?;
    shared.continuous_batching = cfg.continuous_batching;
    let shared = Arc::new(shared);

    // Spawn workers per component (each carries its node's degrade knob
    // so it can shed fidelity when the shared overload cell says so).
    let mut workers: HashMap<NodeId, Vec<WorkerHandle>> = HashMap::new();
    for node in graph.work_nodes() {
        let n = cfg
            .instances
            .as_ref()
            .and_then(|m| m.get(&node.name).copied())
            .unwrap_or_else(|| node.base_instances.max(1));
        let v: Vec<WorkerHandle> = (0..n)
            .map(|i| {
                spawn_for_kind(
                    format!("{}-{i}", node.name),
                    &node.kind,
                    node.degrade,
                    shared.clone(),
                )
            })
            .collect();
        workers.insert(node.id, v);
    }

    let (tx, rx) = channel::<Msg>();
    // Bridge worker completions into the controller's single channel.
    let (done_tx, done_rx) = channel::<Done>();
    {
        let tx = tx.clone();
        std::thread::spawn(move || {
            for d in done_rx {
                if tx.send(Msg::Done(d)).is_err() {
                    break;
                }
            }
        });
    }

    // The shared control plane: same policy object the DES drives, wired
    // to the workers' overload cell + counters, ticked by the wall clock.
    // The generator prior is priced under the batching mode — and at the
    // decode occupancy — the workers actually run (the engine batches at
    // its largest compiled bucket, which matches WORKER_SLOTS), so the
    // slack predictor's seed (and with it admission control) sees real
    // batched-decode economics, not the per-instance DES slot count.
    let gen_mode = if cfg.continuous_batching {
        crate::profile::GenBatching::Continuous
    } else {
        crate::profile::GenBatching::Static
    };
    let prior = profile_graph_gen_at(&graph, 200, cfg.seed ^ 0x5CED, gen_mode, WORKER_SLOTS);
    let plane = ControlPlane::new(
        &graph,
        &prior.mean_service,
        RoutingPolicy::LoadStateAware,
        QueueDiscipline::LeastSlack,
        cfg.sched,
        10.0,
    )
    .share(shared.degrade.clone(), shared.sched_counters.clone());

    let slo = cfg.slo;
    let cache = shared.cache.clone();
    let kv_cache = shared.kv_cache.clone();
    let k_docs = shared.k_docs;
    let max_new_tokens = shared.max_new_tokens;
    let join = std::thread::Builder::new()
        .name("harmonia-controller".into())
        .spawn(move || {
            controller_loop(ControllerLoop {
                graph,
                workers,
                rx,
                done_tx,
                slo,
                cache,
                kv_cache,
                plane,
                k_docs,
                max_new_tokens,
            })
        })
        .expect("spawn controller");

    Ok(ServingHandle { tx, join: Some(join) })
}

/// Everything the controller thread owns.
struct ControllerLoop {
    graph: PipelineGraph,
    workers: HashMap<NodeId, Vec<WorkerHandle>>,
    rx: Receiver<Msg>,
    done_tx: Sender<Done>,
    slo: Option<f64>,
    cache: Option<Arc<crate::cache::QueryCache>>,
    kv_cache: Option<Arc<crate::cache::KvPrefixCache>>,
    plane: ControlPlane,
    k_docs: usize,
    max_new_tokens: usize,
}

fn controller_loop(lp: ControllerLoop) {
    let ControllerLoop {
        graph,
        workers,
        rx,
        done_tx,
        slo,
        cache,
        kv_cache,
        mut plane,
        k_docs,
        max_new_tokens,
    } = lp;
    let mut recorder = Recorder::new();
    let mut inflight: HashMap<u64, InflightReq> = HashMap::new();
    let mut next_req: u64 = 0;
    let clock = WallClock::new();
    let mut last_tick = 0.0f64;
    let mut rng = crate::util::rng::Rng::new(0x11FE);

    let total_slots: usize = workers.values().map(|v| v.len() * WORKER_SLOTS).sum();
    let stateful_map: HashMap<NodeId, bool> =
        graph.nodes.iter().map(|n| (n.id, n.stateful)).collect();
    // Dense fork index from the spec compiler (branch entries + join +
    // barrier policy per fork node); the controller dispatches ALL fork
    // successors at once and merges their `Done`s at the join cell.
    let fork_map = graph.analyze().fork_map;
    let dispatch = |req: u64,
                    node: NodeId,
                    branch: u32,
                    state: RagState,
                    plane: &mut ControlPlane,
                    workers: &HashMap<NodeId, Vec<WorkerHandle>>,
                    done_tx: &Sender<Done>| {
        let pool = &workers[&node];
        let states: Vec<InstanceState> = pool
            .iter()
            .map(|w| InstanceState {
                active: w.pending().min(WORKER_SLOTS),
                queued: w.pending().saturating_sub(WORKER_SLOTS),
                slots: WORKER_SLOTS,
                expected_reentries: 0.0,
                up: w.is_up(),
            })
            .collect();
        let stateful = stateful_map.get(&node).copied().unwrap_or(false);
        let pick = plane.route(req, node, stateful, &states);
        let item = WorkItem::for_branch(req, node, branch, state, done_tx.clone());
        let _ = pool[pick].submit(item);
    };

    loop {
        // The unified control tick, wall-clock driven. Live queues are
        // worker channels (FIFO by construction), so the tick's rekey
        // outcome has nothing to reorder here; reallocation needs worker
        // spawn/drain and stays sim-only for now — hence `realloc: None`.
        let now = clock.now();
        if now - last_tick >= TICK_INTERVAL {
            last_tick = now;
            let pending: usize = workers.values().flatten().map(|w| w.pending()).sum();
            let util = pending as f64 / total_slots.max(1) as f64;
            let _ = plane.tick(now, util, None);
        }

        let msg = match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        match msg {
            Msg::Submit { query, resp } => {
                let req = next_req;
                next_req += 1;
                let now = clock.now();
                recorder.on_arrival(now);
                let entry = graph
                    .successors(graph.source)
                    .next()
                    .expect("source successor")
                    .to;
                // Live features: prompt bytes stand in for token counts;
                // retrieval volume and generation budget come from the
                // deployment, so the slack regressors see real signals.
                let features = RequestFeatures {
                    prompt_len: query.len().clamp(4, 127),
                    gen_len: max_new_tokens,
                    k_docs,
                    complexity: 1,
                };
                if plane.admission_enabled() {
                    let pool = &workers[&entry];
                    // Queued work only (pending minus the slots actively
                    // executing), matching the DES's node_load semantics
                    // so one AdmissionConfig means the same thresholds on
                    // both backends.
                    let queued: usize = pool
                        .iter()
                        .map(|w| w.pending().saturating_sub(WORKER_SLOTS))
                        .sum();
                    let capacity = pool.len() * WORKER_SLOTS;
                    let deadline = slo.map(|s| now + s);
                    let decision =
                        plane.admit(entry, &features, now, deadline, queued, capacity);
                    if !decision.admitted() {
                        recorder.on_shed();
                        let _ = resp.send(LiveResponse {
                            req,
                            answer: Vec::new(),
                            latency_secs: 0.0,
                            hops: 0,
                            error: Some(format!("shed by admission control: {decision:?}")),
                        });
                        continue;
                    }
                }
                let state = RagState::new(&query);
                inflight.insert(
                    req,
                    InflightReq {
                        resp,
                        started: Instant::now(),
                        deadline: slo,
                        hops: 0,
                        current: entry,
                        features,
                        next_branch: 0,
                        joins: HashMap::new(),
                    },
                );
                // A fork at the pipeline entry fans out immediately
                // (hybrid retrieval: dense ∥ web from the first hop).
                if let Some(fg) = fork_map[graph.source.0].as_ref() {
                    let fl = inflight.get_mut(&req).expect("just inserted");
                    let mut cell = LiveJoin::new(fg);
                    let mut spawned = Vec::with_capacity(fg.targets.len());
                    for &target in &fg.targets {
                        fl.next_branch += 1;
                        cell.branches.insert(fl.next_branch);
                        spawned.push((fl.next_branch, target));
                    }
                    fl.joins.insert(fg.join, cell);
                    for (b, target) in spawned {
                        dispatch(req, target, b, state.clone(), &mut plane, &workers, &done_tx);
                    }
                } else {
                    dispatch(req, entry, 0, state, &mut plane, &workers, &done_tx);
                }
            }
            Msg::Done(d) => {
                let Some(fl) = inflight.get_mut(&d.req) else { continue };
                fl.hops += 1;
                let node_name = graph.node(d.node).name.clone();
                recorder.on_execution(&node_name, d.service_secs, d.queue_secs);
                let features = fl.features;
                if let Some(err) = d.error {
                    let fl = inflight.remove(&d.req).unwrap();
                    let _ = fl.resp.send(LiveResponse {
                        req: d.req,
                        answer: Vec::new(),
                        latency_secs: fl.started.elapsed().as_secs_f64(),
                        hops: fl.hops,
                        error: Some(err),
                    });
                    plane.release(d.req);
                    continue;
                }
                // Successful completions only: an errored item reports
                // service_secs ≈ 0 (worker init failure), and feeding that
                // into the slack regressors would collapse predictions to
                // zero exactly when admission control needs them.
                plane.on_complete(d.node, d.service_secs);
                plane.observe_service(d.node, &features, d.service_secs);
                // Parallel fan-out: a fork node's completion dispatches
                // EVERY branch at once, each tagged with its own branch
                // id and reporting to a fresh join cell.
                if let Some(fg) = fork_map[d.node.0].as_ref() {
                    let mut cell = LiveJoin::new(fg);
                    let mut spawned = Vec::with_capacity(fg.targets.len());
                    for &target in &fg.targets {
                        fl.next_branch += 1;
                        cell.branches.insert(fl.next_branch);
                        spawned.push((fl.next_branch, target));
                    }
                    fl.joins.insert(fg.join, cell);
                    for (b, target) in spawned {
                        dispatch(d.req, target, b, d.state.clone(), &mut plane, &workers, &done_tx);
                    }
                    continue;
                }
                let next = decide_next(&graph, d.node, &d.state, &mut rng);
                // A branch completion bound for a join node reports to
                // the barrier instead of dispatching the join directly.
                if next != graph.sink && graph.node(next).join.is_some() {
                    if let Some(cell) = fl.joins.get_mut(&next) {
                        if cell.branches.contains(&d.branch) {
                            if cell.fired {
                                // Late FirstK loser: state dropped; its
                                // worker slot was already released by
                                // the Done itself.
                                continue;
                            }
                            cell.states.push(d.state);
                            cell.arrivals.push(Instant::now());
                            if cell.states.len() < cell.need {
                                continue;
                            }
                            cell.fired = true;
                            // Losers still in flight retire harmlessly
                            // at the `fired` gate above — queue and
                            // engine state stay consistent.
                            let merged =
                                RagState::merge(cell.merge, std::mem::take(&mut cell.states));
                            let release = *cell.arrivals.last().expect("at least one arrival");
                            let stall: f64 = cell.arrivals[..cell.arrivals.len() - 1]
                                .iter()
                                .map(|t| release.duration_since(*t).as_secs_f64())
                                .sum();
                            recorder.on_join_wait(&graph.node(next).name, stall);
                            fl.current = next;
                            dispatch(d.req, next, 0, merged, &mut plane, &workers, &done_tx);
                            continue;
                        }
                        if d.branch != 0 {
                            // Stale loser from a PREVIOUS traversal of
                            // this fork (recursion wrapped a FirstK
                            // race): it must neither merge into nor
                            // release the fresh barrier.
                            continue;
                        }
                        // Trunk arrival (no branch context): not a
                        // barrier member — fall through to a normal hop.
                    }
                }
                if next == graph.sink {
                    let fl = inflight.remove(&d.req).unwrap();
                    let latency = fl.started.elapsed().as_secs_f64();
                    let now = clock.now();
                    recorder.on_completion(now - latency, now, fl.deadline.map(|s| now - latency + s));
                    let _ = fl.resp.send(LiveResponse {
                        req: d.req,
                        answer: d.state.answer,
                        latency_secs: latency,
                        hops: fl.hops,
                        error: None,
                    });
                    plane.release(d.req);
                } else {
                    fl.current = next;
                    dispatch(d.req, next, d.branch, d.state, &mut plane, &workers, &done_tx);
                }
            }
            Msg::Report(tx) => {
                if let Some(c) = &cache {
                    recorder.set_cache(c.snapshot());
                }
                if let Some(kc) = &kv_cache {
                    recorder.set_kv_prefix(kc.snapshot());
                }
                if plane.cfg.enabled() {
                    recorder.set_sched(plane.counters.snapshot());
                }
                let _ = tx.send(recorder.report());
            }
            Msg::Shutdown => break,
        }
    }
    for (_, pool) in workers {
        for w in pool {
            w.shutdown();
        }
    }
}

/// Control-flow decision: maps (node kind, request state) to the next
/// node — the live counterpart of the program's `if`/`while` structure
/// (Fig. 7). Falls back to probability-weighted choice for custom nodes.
pub fn decide_next(
    graph: &PipelineGraph,
    node: NodeId,
    state: &RagState,
    rng: &mut crate::util::rng::Rng,
) -> NodeId {
    let succ: Vec<_> = graph.successors(node).collect();
    debug_assert!(!succ.is_empty());
    if succ.len() == 1 {
        return succ[0].to;
    }
    let kind = &graph.node(node).kind;
    match kind {
        ComponentKind::Grader => {
            // Relevant context → straight to a generator; else rewrite.
            let want_generator = state.verdict.unwrap_or(true);
            pick_by(graph, &succ, |k| {
                if want_generator {
                    matches!(k, ComponentKind::Generator)
                } else {
                    !matches!(k, ComponentKind::Generator)
                }
            })
        }
        ComponentKind::Critic => {
            // Accept (or iteration budget exhausted) → sink; else loop.
            let accept = state.verdict.unwrap_or(true) || state.iteration >= 2;
            if accept {
                succ.iter()
                    .find(|e| e.to == graph.sink)
                    .map(|e| e.to)
                    .unwrap_or(succ[0].to)
            } else {
                succ.iter()
                    .find(|e| e.to != graph.sink)
                    .map(|e| e.to)
                    .unwrap_or(succ[0].to)
            }
        }
        ComponentKind::Classifier => {
            let class = state.class.unwrap_or(1);
            match class {
                0 => pick_by(graph, &succ, |k| matches!(k, ComponentKind::Generator)),
                2 => succ
                    .iter()
                    .find(|e| graph.node(e.to).name.starts_with("iter"))
                    .map(|e| e.to)
                    .unwrap_or_else(|| {
                        pick_by(graph, &succ, |k| matches!(k, ComponentKind::Retriever))
                    }),
                _ => succ
                    .iter()
                    .find(|e| {
                        matches!(graph.node(e.to).kind, ComponentKind::Retriever)
                            && !graph.node(e.to).name.starts_with("iter")
                    })
                    .map(|e| e.to)
                    .unwrap_or(succ[0].to),
            }
        }
        _ => {
            // Probability-weighted (spec priors).
            let weights: Vec<f64> = succ.iter().map(|e| e.prob()).collect();
            succ[rng.weighted(&weights)].to
        }
    }
}

fn pick_by(
    graph: &PipelineGraph,
    succ: &[&crate::spec::graph::EdgeSpec],
    pred: impl Fn(&ComponentKind) -> bool,
) -> NodeId {
    succ.iter()
        .find(|e| pred(&graph.node(e.to).kind))
        .map(|e| e.to)
        .unwrap_or(succ[0].to)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;
    use crate::util::rng::Rng;

    #[test]
    fn decide_next_linear_pipeline() {
        let g = apps::vanilla_rag();
        let mut rng = Rng::new(0);
        let retr = g.node_by_name("retriever").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let s = RagState::new(b"q");
        assert_eq!(decide_next(&g, retr, &s, &mut rng), gen);
        assert_eq!(decide_next(&g, gen, &s, &mut rng), g.sink);
    }

    #[test]
    fn decide_next_crag_branches_on_verdict() {
        let g = apps::corrective_rag();
        let mut rng = Rng::new(0);
        let grader = g.node_by_name("grader").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        let rewriter = g.node_by_name("rewriter").unwrap().id;
        let mut s = RagState::new(b"q");
        s.verdict = Some(true);
        assert_eq!(decide_next(&g, grader, &s, &mut rng), gen);
        s.verdict = Some(false);
        assert_eq!(decide_next(&g, grader, &s, &mut rng), rewriter);
    }

    #[test]
    fn decide_next_srag_loop_bounded() {
        let g = apps::self_rag();
        let mut rng = Rng::new(0);
        let critic = g.node_by_name("critic").unwrap().id;
        let rewriter = g.node_by_name("rewriter").unwrap().id;
        let mut s = RagState::new(b"q");
        s.verdict = Some(false);
        s.iteration = 0;
        assert_eq!(decide_next(&g, critic, &s, &mut rng), rewriter);
        // Budget exhausted: must exit even on reject.
        s.iteration = 2;
        assert_eq!(decide_next(&g, critic, &s, &mut rng), g.sink);
    }

    #[test]
    fn decide_next_arag_routes_by_class() {
        let g = apps::adaptive_rag();
        let mut rng = Rng::new(0);
        let cls = g.node_by_name("classifier").unwrap().id;
        let mut s = RagState::new(b"q");
        s.class = Some(0);
        assert_eq!(
            decide_next(&g, cls, &s, &mut rng),
            g.node_by_name("generator").unwrap().id
        );
        s.class = Some(1);
        assert_eq!(
            decide_next(&g, cls, &s, &mut rng),
            g.node_by_name("retriever").unwrap().id
        );
        s.class = Some(2);
        assert_eq!(
            decide_next(&g, cls, &s, &mut rng),
            g.node_by_name("iter_retriever").unwrap().id
        );
    }
}
