//! Telemetry-driven resource reallocation (§3.3.1).
//!
//! Every `interval` seconds the controller re-estimates (α, γ, p) from
//! [`Telemetry`] and re-solves the Fig. 8 LP in the background; a new
//! allocation is committed only when **two consecutive solutions agree**
//! (the paper's damping rule), avoiding thrash on noisy estimates.

use std::collections::HashMap;
use std::time::Instant;

use crate::alloc::FlowProblem;
use crate::profile::models::instance_concurrency;
use crate::profile::Profile;
use crate::spec::graph::{NodeId, PipelineGraph, ResourceKind};

use super::telemetry::Telemetry;

/// The periodic re-solver.
pub struct Autoscaler {
    pub interval: f64,
    last_solve: f64,
    pending: Option<HashMap<NodeId, usize>>,
    /// Wall-clock seconds of each LP solve (Fig. 12 / §3.3.1 overhead).
    pub solve_times: Vec<f64>,
    /// Committed reallocations (time, plan).
    pub commits: Vec<(f64, HashMap<NodeId, usize>)>,
}

impl Autoscaler {
    pub fn new(interval: f64) -> Self {
        Autoscaler {
            interval,
            last_solve: f64::NEG_INFINITY,
            pending: None,
            solve_times: Vec::new(),
            commits: Vec::new(),
        }
    }

    /// Build a Profile from live telemetry (α from observed service
    /// rates; p from observed branch frequencies; γ from the spec),
    /// falling back to `prior` where telemetry is still cold.
    pub fn telemetry_profile(
        graph: &PipelineGraph,
        telemetry: &Telemetry,
        prior: &Profile,
    ) -> Profile {
        let mut mean_service = HashMap::new();
        let mut alpha = HashMap::new();
        let mut gen_split = HashMap::new();
        for node in &graph.nodes {
            let prior_mean = prior.mean_service.get(&node.id).copied().unwrap_or(0.0);
            let mean = telemetry.mean_service(node.id, prior_mean);
            mean_service.insert(node.id, mean);
            if mean > 0.0 {
                let conc = instance_concurrency(&node.kind) as f64;
                for &(k, units) in &node.resources {
                    if units > 0.0 {
                        alpha.insert((node.id, k), conc / mean / units);
                    }
                }
            }
            // Telemetry reports the aggregate only; keep the prior's
            // prefill/decode *ratio* and rescale it to the observed mean
            // so disaggregated re-solves track drift in either phase.
            if let Some(s) = prior.gen_split.get(&node.id) {
                let ratio = if prior_mean > 0.0 { mean / prior_mean } else { 1.0 };
                gen_split.insert(
                    node.id,
                    crate::profile::profiler::GenSplit {
                        prefill: s.prefill * ratio,
                        decode: s.decode * ratio,
                        prompt_tokens: s.prompt_tokens,
                    },
                );
            }
        }
        Profile {
            mean_service,
            alpha,
            edge_probs: telemetry.edge_probs(graph),
            gamma: prior.gamma.clone(),
            gen_split,
            samples: prior.samples,
        }
    }

    /// Called on the control tick. Returns a newly *committed* instance
    /// plan if two consecutive solves agreed; otherwise None.
    pub fn maybe_rescale(
        &mut self,
        now: f64,
        graph: &PipelineGraph,
        telemetry: &Telemetry,
        prior: &Profile,
        budgets: &[(ResourceKind, f64)],
    ) -> Option<HashMap<NodeId, usize>> {
        if now - self.last_solve < self.interval {
            return None;
        }
        self.last_solve = now;
        let profile = Self::telemetry_profile(graph, telemetry, prior);
        let t0 = Instant::now();
        let plan = FlowProblem::new(graph, &profile, budgets.to_vec()).solve().ok()?;
        self.solve_times.push(t0.elapsed().as_secs_f64());
        // Scale targets are deployable units: for sharded components one
        // unit is a complete replica set (the runtime's schedulable
        // quantum), for everything else a plain instance.
        let counts: HashMap<NodeId, usize> =
            graph.work_nodes().map(|n| (n.id, plan.units(n.id))).collect();
        match &self.pending {
            Some(prev) if plans_agree(prev, &counts) => {
                self.pending = None;
                self.commits.push((now, counts.clone()));
                Some(counts)
            }
            _ => {
                self.pending = Some(counts);
                None
            }
        }
    }
}

/// Two consecutive solutions "agree" when every component's instance
/// count differs by at most 1 (telemetry keeps moving, so exact equality
/// would never commit; ±1 keeps the paper's damping intent).
fn plans_agree(a: &HashMap<NodeId, usize>, b: &HashMap<NodeId, usize>) -> bool {
    let keys: std::collections::HashSet<_> = a.keys().chain(b.keys()).collect();
    keys.into_iter().all(|k| {
        let x = a.get(k).copied().unwrap_or(0) as i64;
        let y = b.get(k).copied().unwrap_or(0) as i64;
        (x - y).abs() <= 1
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::flow::paper_cluster_budgets;
    use crate::profile::profile_graph;
    use crate::spec::apps;

    #[test]
    fn requires_two_agreeing_solutions() {
        let g = apps::vanilla_rag();
        let prior = profile_graph(&g, 1000, 0);
        let telemetry = Telemetry::new(&g);
        let budgets = paper_cluster_budgets();
        let mut a = Autoscaler::new(10.0);
        // First solve: pending, no commit.
        assert!(a.maybe_rescale(0.0, &g, &telemetry, &prior, &budgets).is_none());
        // Within the interval: no solve at all.
        assert!(a.maybe_rescale(5.0, &g, &telemetry, &prior, &budgets).is_none());
        assert_eq!(a.solve_times.len(), 1);
        // Second solve agrees (same telemetry): commit.
        let plan = a.maybe_rescale(10.0, &g, &telemetry, &prior, &budgets);
        assert!(plan.is_some());
        assert_eq!(a.commits.len(), 1);
    }

    #[test]
    fn telemetry_shifts_the_allocation() {
        // Make the generator look 4× slower than the prior believed; the
        // re-solved plan should shift GPU instances toward it.
        let g = apps::corrective_rag();
        let prior = profile_graph(&g, 2000, 1);
        let budgets = paper_cluster_budgets();
        let mut telemetry = Telemetry::new(&g);
        let gen = g.node_by_name("generator").unwrap().id;
        let grader = g.node_by_name("grader").unwrap().id;
        for _ in 0..500 {
            telemetry.on_enqueue(gen);
            telemetry.on_complete(gen, prior.mean_service[&gen] * 4.0);
            telemetry.on_enqueue(grader);
            telemetry.on_complete(grader, prior.mean_service[&grader]);
        }
        let mut a = Autoscaler::new(0.0);
        a.maybe_rescale(0.0, &g, &telemetry, &prior, &budgets);
        let plan = a.maybe_rescale(1.0, &g, &telemetry, &prior, &budgets).unwrap();

        // Compare with the prior-only plan.
        let base = FlowProblem::new(&g, &prior, budgets.clone()).solve().unwrap();
        assert!(
            plan[&gen] > base.instance_counts[&gen],
            "reallocation should add generators: {} vs {}",
            plan[&gen],
            base.instance_counts[&gen]
        );
    }

    #[test]
    fn solve_time_recorded() {
        let g = apps::self_rag();
        let prior = profile_graph(&g, 500, 2);
        let telemetry = Telemetry::new(&g);
        let mut a = Autoscaler::new(0.0);
        a.maybe_rescale(0.0, &g, &telemetry, &prior, &paper_cluster_budgets());
        assert_eq!(a.solve_times.len(), 1);
        assert!(a.solve_times[0] > 0.0 && a.solve_times[0] < 1.0);
    }
}
