//! Load- and state-aware routing (§3.3.1).
//!
//! The Harmonia policy scores every candidate instance by *predicted*
//! near-future load: current active slots + queue, **plus** outstanding
//! stateful iterations expected to re-enter that instance (capacity that
//! looks idle but is spoken for). Ray-like dispatch ("idle-worker") is the
//! baseline policy the paper contrasts (§5 "Comparison with Ray").

use std::collections::HashMap;

use crate::spec::graph::NodeId;

/// Router-visible state of one component instance.
#[derive(Clone, Debug, Default)]
pub struct InstanceState {
    /// Requests currently executing.
    pub active: usize,
    /// Requests waiting in the instance queue.
    pub queued: usize,
    /// Concurrency limit (slots).
    pub slots: usize,
    /// Outstanding stateful requests bound here that are expected to
    /// return (the "reserved capacity" signal).
    pub expected_reentries: f64,
    /// Is the instance up (autoscaler may be draining it)?
    pub up: bool,
}

impl InstanceState {
    pub fn idle_slots(&self) -> usize {
        self.slots.saturating_sub(self.active)
    }
}

/// Routing policies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Harmonia: minimize active + queued + expected stateful re-entries.
    LoadStateAware,
    /// Ray/Haystack-like: first idle instance, else shortest queue;
    /// ignores reserved stateful capacity.
    IdleFirst,
    /// Round-robin (LangChain-style top-level replica selection).
    RoundRobin,
}

/// Stateful-binding table + routing logic.
#[derive(Clone, Debug)]
pub struct Router {
    policy: RoutingPolicy,
    /// (request, node) → instance index, for stateful components.
    bindings: HashMap<(u64, NodeId), usize>,
    rr_counters: HashMap<NodeId, usize>,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Self {
        Router { policy, bindings: HashMap::new(), rr_counters: HashMap::new() }
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Choose an instance for `request` at `node`. `stateful` components
    /// honor existing bindings (correctness, all policies); new bindings
    /// are recorded. Returns the instance index.
    pub fn route(
        &mut self,
        request: u64,
        node: NodeId,
        stateful: bool,
        instances: &[InstanceState],
    ) -> usize {
        debug_assert!(!instances.is_empty());
        if stateful {
            if let Some(&inst) = self.bindings.get(&(request, node)) {
                if inst < instances.len() && instances[inst].up {
                    return inst;
                }
            }
        }
        let pick = match self.policy {
            RoutingPolicy::LoadStateAware => self.pick_load_state_aware(instances),
            RoutingPolicy::IdleFirst => self.pick_idle_first(instances),
            RoutingPolicy::RoundRobin => self.pick_round_robin(node, instances),
        };
        if stateful {
            self.bindings.insert((request, node), pick);
        }
        pick
    }

    /// Drop a request's bindings once it completes.
    pub fn release(&mut self, request: u64) {
        self.bindings.retain(|(r, _), _| *r != request);
    }

    pub fn bindings_for(&self, node: NodeId) -> usize {
        self.bindings.keys().filter(|(_, n)| *n == node).count()
    }

    /// Total stateful bindings currently held across all nodes — the
    /// slot-leak audit's probe: every terminal path (completion, shed,
    /// error, cancelled fork loser) must leave this at 0 once the system
    /// drains.
    pub fn total_bindings(&self) -> usize {
        self.bindings.len()
    }

    fn pick_load_state_aware(&self, instances: &[InstanceState]) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (i, s) in instances.iter().enumerate() {
            if !s.up {
                continue;
            }
            // Normalized predicted occupancy: lower is better. Queued work
            // and expected re-entries count toward future load.
            let slots = s.slots.max(1) as f64;
            let score = (s.active as f64 + s.queued as f64 + s.expected_reentries) / slots;
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    fn pick_idle_first(&self, instances: &[InstanceState]) -> usize {
        // First instance with a free slot (instantaneous view only).
        for (i, s) in instances.iter().enumerate() {
            if s.up && s.idle_slots() > 0 && s.queued == 0 {
                return i;
            }
        }
        // Else: shortest queue.
        let mut best = 0;
        let mut best_q = usize::MAX;
        for (i, s) in instances.iter().enumerate() {
            if s.up && s.queued + s.active < best_q {
                best_q = s.queued + s.active;
                best = i;
            }
        }
        best
    }

    fn pick_round_robin(&mut self, node: NodeId, instances: &[InstanceState]) -> usize {
        let c = self.rr_counters.entry(node).or_insert(0);
        for _ in 0..instances.len() {
            let i = *c % instances.len();
            *c += 1;
            if instances[i].up {
                return i;
            }
        }
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst(active: usize, queued: usize, slots: usize, reent: f64) -> InstanceState {
        InstanceState { active, queued, slots, expected_reentries: reent, up: true }
    }

    #[test]
    fn load_aware_picks_least_loaded() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let instances = vec![inst(3, 2, 4, 0.0), inst(1, 0, 4, 0.0), inst(2, 1, 4, 0.0)];
        assert_eq!(r.route(1, NodeId(2), false, &instances), 1);
    }

    #[test]
    fn state_aware_avoids_reserved_capacity() {
        // Instance 0 looks idle but expects stateful re-entries; Harmonia
        // avoids it, idle-first does not.
        let instances = vec![inst(0, 0, 4, 3.5), inst(1, 0, 4, 0.0)];
        let mut h = Router::new(RoutingPolicy::LoadStateAware);
        assert_eq!(h.route(1, NodeId(2), false, &instances), 1);
        let mut ray = Router::new(RoutingPolicy::IdleFirst);
        assert_eq!(ray.route(1, NodeId(2), false, &instances), 0);
    }

    #[test]
    fn stateful_binding_is_sticky() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let instances = vec![inst(0, 0, 4, 0.0), inst(0, 0, 4, 0.0)];
        let first = r.route(7, NodeId(3), true, &instances);
        // Overload the bound instance; routing must stick anyway.
        let mut loaded = instances.clone();
        loaded[first] = inst(4, 9, 4, 0.0);
        let second = r.route(7, NodeId(3), true, &loaded);
        assert_eq!(first, second);
        // A different request is free to go elsewhere.
        let other = r.route(8, NodeId(3), true, &loaded);
        assert_ne!(other, first);
    }

    #[test]
    fn release_clears_bindings() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let instances = vec![inst(0, 0, 1, 0.0), inst(0, 0, 1, 0.0)];
        r.route(7, NodeId(3), true, &instances);
        assert_eq!(r.bindings_for(NodeId(3)), 1);
        r.release(7);
        assert_eq!(r.bindings_for(NodeId(3)), 0);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let instances = vec![inst(0, 0, 1, 0.0); 3];
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(i, NodeId(1), false, &instances)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn down_instances_skipped() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let mut instances = vec![inst(0, 0, 4, 0.0), inst(2, 2, 4, 0.0)];
        instances[0].up = false;
        assert_eq!(r.route(1, NodeId(2), false, &instances), 1);
    }
}
