//! Load- and state-aware routing (§3.3.1).
//!
//! The Harmonia policy scores every candidate instance by *predicted*
//! near-future load: current active slots + queue, **plus** outstanding
//! stateful iterations expected to re-enter that instance (capacity that
//! looks idle but is spoken for). Ray-like dispatch ("idle-worker") is the
//! baseline policy the paper contrasts (§5 "Comparison with Ray").
//!
//! Hot-path representation: stateful bindings live in a small per-request
//! *arena* (`request → Vec<(node, instance)>`), so a route probe hashes
//! once on the request id and scans a tiny vector instead of hashing a
//! composite `(request, node)` key, and releasing a finished request is a
//! single map removal instead of a full-table retain. Round-robin
//! counters are a dense `Vec` indexed by `NodeId` (pre-sized via
//! [`Router::with_nodes`]) — no per-route hash probe keyed by node.

use std::collections::HashMap;

use crate::spec::graph::NodeId;

/// Router-visible state of one component instance.
#[derive(Clone, Debug, Default)]
pub struct InstanceState {
    /// Requests currently executing.
    pub active: usize,
    /// Requests waiting in the instance queue.
    pub queued: usize,
    /// Concurrency limit (slots).
    pub slots: usize,
    /// Outstanding stateful requests bound here that are expected to
    /// return (the "reserved capacity" signal).
    pub expected_reentries: f64,
    /// Is the instance up (autoscaler may be draining it)?
    pub up: bool,
}

impl InstanceState {
    pub fn idle_slots(&self) -> usize {
        self.slots.saturating_sub(self.active)
    }
}

/// Routing policies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Harmonia: minimize active + queued + expected stateful re-entries.
    LoadStateAware,
    /// Ray/Haystack-like: first idle instance, else shortest queue;
    /// ignores reserved stateful capacity.
    IdleFirst,
    /// Round-robin (LangChain-style top-level replica selection).
    RoundRobin,
}

/// Stateful-binding table + routing logic.
#[derive(Clone, Debug)]
pub struct Router {
    policy: RoutingPolicy,
    /// request → its stateful (node, instance) bindings. A request binds
    /// at most a handful of nodes, so the arena is a linear-scanned Vec.
    bindings: HashMap<u64, Vec<(NodeId, usize)>>,
    /// Total bindings across all arenas (kept incrementally so the
    /// slot-leak audit stays O(1)).
    n_bindings: usize,
    /// Dense per-node round-robin cursors (grown on demand for nodes
    /// beyond the pre-sized range).
    rr_counters: Vec<usize>,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Self {
        Router::with_nodes(policy, 0)
    }

    /// Pre-size the dense per-node state for a graph of `n_nodes` nodes.
    pub fn with_nodes(policy: RoutingPolicy, n_nodes: usize) -> Self {
        Router {
            policy,
            bindings: HashMap::new(),
            n_bindings: 0,
            rr_counters: vec![0; n_nodes],
        }
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Choose an instance for `request` at `node`. `stateful` components
    /// honor existing bindings (correctness, all policies); new bindings
    /// are recorded. Returns the instance index.
    pub fn route(
        &mut self,
        request: u64,
        node: NodeId,
        stateful: bool,
        instances: &[InstanceState],
    ) -> usize {
        debug_assert!(!instances.is_empty());
        if stateful {
            if let Some(arena) = self.bindings.get(&request) {
                if let Some(&(_, inst)) = arena.iter().find(|(n, _)| *n == node) {
                    if inst < instances.len() && instances[inst].up {
                        return inst;
                    }
                }
            }
        }
        let pick = match self.policy {
            RoutingPolicy::LoadStateAware => self.pick_load_state_aware(instances),
            RoutingPolicy::IdleFirst => self.pick_idle_first(instances),
            RoutingPolicy::RoundRobin => self.pick_round_robin(node, instances),
        };
        if stateful {
            let arena = self.bindings.entry(request).or_default();
            match arena.iter_mut().find(|(n, _)| *n == node) {
                // Rebind (stale binding to a down/vanished instance).
                Some(e) => e.1 = pick,
                None => {
                    arena.push((node, pick));
                    self.n_bindings += 1;
                }
            }
        }
        pick
    }

    /// Drop a request's bindings once it completes (O(1): the whole
    /// arena goes at once).
    pub fn release(&mut self, request: u64) {
        if let Some(arena) = self.bindings.remove(&request) {
            self.n_bindings -= arena.len();
        }
    }

    pub fn bindings_for(&self, node: NodeId) -> usize {
        self.bindings.values().map(|a| a.iter().filter(|(n, _)| *n == node).count()).sum()
    }

    /// Total stateful bindings currently held across all nodes — the
    /// slot-leak audit's probe: every terminal path (completion, shed,
    /// error, cancelled fork loser) must leave this at 0 once the system
    /// drains.
    pub fn total_bindings(&self) -> usize {
        self.n_bindings
    }

    fn pick_load_state_aware(&self, instances: &[InstanceState]) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (i, s) in instances.iter().enumerate() {
            if !s.up {
                continue;
            }
            // Normalized predicted occupancy: lower is better. Queued work
            // and expected re-entries count toward future load.
            let slots = s.slots.max(1) as f64;
            let score = (s.active as f64 + s.queued as f64 + s.expected_reentries) / slots;
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    fn pick_idle_first(&self, instances: &[InstanceState]) -> usize {
        // First instance with a free slot (instantaneous view only).
        for (i, s) in instances.iter().enumerate() {
            if s.up && s.idle_slots() > 0 && s.queued == 0 {
                return i;
            }
        }
        // Else: shortest queue.
        let mut best = 0;
        let mut best_q = usize::MAX;
        for (i, s) in instances.iter().enumerate() {
            if s.up && s.queued + s.active < best_q {
                best_q = s.queued + s.active;
                best = i;
            }
        }
        best
    }

    fn pick_round_robin(&mut self, node: NodeId, instances: &[InstanceState]) -> usize {
        if node.0 >= self.rr_counters.len() {
            self.rr_counters.resize(node.0 + 1, 0);
        }
        let c = &mut self.rr_counters[node.0];
        for _ in 0..instances.len() {
            let i = *c % instances.len();
            *c += 1;
            if instances[i].up {
                return i;
            }
        }
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn inst(active: usize, queued: usize, slots: usize, reent: f64) -> InstanceState {
        InstanceState { active, queued, slots, expected_reentries: reent, up: true }
    }

    #[test]
    fn load_aware_picks_least_loaded() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let instances = vec![inst(3, 2, 4, 0.0), inst(1, 0, 4, 0.0), inst(2, 1, 4, 0.0)];
        assert_eq!(r.route(1, NodeId(2), false, &instances), 1);
    }

    #[test]
    fn state_aware_avoids_reserved_capacity() {
        // Instance 0 looks idle but expects stateful re-entries; Harmonia
        // avoids it, idle-first does not.
        let instances = vec![inst(0, 0, 4, 3.5), inst(1, 0, 4, 0.0)];
        let mut h = Router::new(RoutingPolicy::LoadStateAware);
        assert_eq!(h.route(1, NodeId(2), false, &instances), 1);
        let mut ray = Router::new(RoutingPolicy::IdleFirst);
        assert_eq!(ray.route(1, NodeId(2), false, &instances), 0);
    }

    #[test]
    fn stateful_binding_is_sticky() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let instances = vec![inst(0, 0, 4, 0.0), inst(0, 0, 4, 0.0)];
        let first = r.route(7, NodeId(3), true, &instances);
        // Overload the bound instance; routing must stick anyway.
        let mut loaded = instances.clone();
        loaded[first] = inst(4, 9, 4, 0.0);
        let second = r.route(7, NodeId(3), true, &loaded);
        assert_eq!(first, second);
        // A different request is free to go elsewhere.
        let other = r.route(8, NodeId(3), true, &loaded);
        assert_ne!(other, first);
    }

    #[test]
    fn release_clears_bindings() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let instances = vec![inst(0, 0, 1, 0.0), inst(0, 0, 1, 0.0)];
        r.route(7, NodeId(3), true, &instances);
        assert_eq!(r.bindings_for(NodeId(3)), 1);
        r.release(7);
        assert_eq!(r.bindings_for(NodeId(3)), 0);
        assert_eq!(r.total_bindings(), 0);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutingPolicy::RoundRobin);
        let instances = vec![inst(0, 0, 1, 0.0); 3];
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(i, NodeId(1), false, &instances)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn down_instances_skipped() {
        let mut r = Router::new(RoutingPolicy::LoadStateAware);
        let mut instances = vec![inst(0, 0, 4, 0.0), inst(2, 2, 4, 0.0)];
        instances[0].up = false;
        assert_eq!(r.route(1, NodeId(2), false, &instances), 1);
    }

    // -- arena representation ≡ the retired composite-key table ------------

    /// The pre-arena router: `(request, node) → instance` composite-key
    /// table with retain-based release. Reproduced verbatim so the
    /// recorded-sequence property below pins the arena representation to
    /// identical instance choices.
    struct FlatRouter {
        policy: RoutingPolicy,
        bindings: HashMap<(u64, NodeId), usize>,
        rr_counters: HashMap<NodeId, usize>,
    }

    impl FlatRouter {
        fn new(policy: RoutingPolicy) -> Self {
            FlatRouter { policy, bindings: HashMap::new(), rr_counters: HashMap::new() }
        }

        fn route(
            &mut self,
            request: u64,
            node: NodeId,
            stateful: bool,
            instances: &[InstanceState],
        ) -> usize {
            if stateful {
                if let Some(&inst) = self.bindings.get(&(request, node)) {
                    if inst < instances.len() && instances[inst].up {
                        return inst;
                    }
                }
            }
            let pick = match self.policy {
                RoutingPolicy::LoadStateAware => {
                    let mut best = 0usize;
                    let mut best_score = f64::INFINITY;
                    for (i, s) in instances.iter().enumerate() {
                        if !s.up {
                            continue;
                        }
                        let slots = s.slots.max(1) as f64;
                        let score =
                            (s.active as f64 + s.queued as f64 + s.expected_reentries) / slots;
                        if score < best_score {
                            best_score = score;
                            best = i;
                        }
                    }
                    best
                }
                RoutingPolicy::IdleFirst => {
                    let mut pick = None;
                    for (i, s) in instances.iter().enumerate() {
                        if s.up && s.idle_slots() > 0 && s.queued == 0 {
                            pick = Some(i);
                            break;
                        }
                    }
                    pick.unwrap_or_else(|| {
                        let mut best = 0;
                        let mut best_q = usize::MAX;
                        for (i, s) in instances.iter().enumerate() {
                            if s.up && s.queued + s.active < best_q {
                                best_q = s.queued + s.active;
                                best = i;
                            }
                        }
                        best
                    })
                }
                RoutingPolicy::RoundRobin => {
                    let c = self.rr_counters.entry(node).or_insert(0);
                    let mut pick = 0;
                    for _ in 0..instances.len() {
                        let i = *c % instances.len();
                        *c += 1;
                        if instances[i].up {
                            pick = i;
                            break;
                        }
                    }
                    pick
                }
            };
            if stateful {
                self.bindings.insert((request, node), pick);
            }
            pick
        }

        fn release(&mut self, request: u64) {
            self.bindings.retain(|(r, _), _| *r != request);
        }
    }

    #[test]
    fn arena_router_matches_flat_router_on_recorded_sequence() {
        for policy in
            [RoutingPolicy::LoadStateAware, RoutingPolicy::IdleFirst, RoutingPolicy::RoundRobin]
        {
            let mut rng = Rng::new(0xA12E);
            let mut arena = Router::new(policy);
            let mut flat = FlatRouter::new(policy);
            for step in 0..2000u64 {
                if rng.chance(0.15) {
                    let req = rng.below(16);
                    arena.release(req);
                    flat.release(req);
                    continue;
                }
                let req = rng.below(16);
                let node = NodeId(rng.index(6));
                let stateful = rng.chance(0.5);
                let n = 1 + rng.index(4);
                let instances: Vec<InstanceState> = (0..n)
                    .map(|_| InstanceState {
                        active: rng.index(5),
                        queued: rng.index(4),
                        slots: 1 + rng.index(8),
                        expected_reentries: rng.index(4) as f64,
                        up: rng.chance(0.85),
                    })
                    .collect();
                assert_eq!(
                    arena.route(req, node, stateful, &instances),
                    flat.route(req, node, stateful, &instances),
                    "policy {policy:?} diverged at step {step}",
                );
            }
        }
    }
}
