//! The **runtime layer** (§3.3): a centralized, SDN-style control plane.
//!
//! All policy logic is written against plain state structs so the *same
//! code* runs under the live controller ([`controller`]) and inside the
//! discrete-event simulator (`sim`) — the paper-scale experiments exercise
//! exactly the policies a live deployment uses.
//!
//! * [`telemetry`] — global view: per-component load, service rates,
//!   observed branch frequencies (re-estimates α, γ, p online).
//! * [`router`] — load- **and state-aware** routing (§3.3.1): stateful
//!   re-entries are pinned; predicted near-future load (outstanding
//!   stateful iterations) is part of the routing score.
//! * `sched::queue` (re-exported here) — deadline-aware EDF with
//!   *predicted slack* (§3.3.2): online linear-regression models map
//!   upstream features to downstream latencies; least-slack requests get
//!   priority. Lives in the shared [`crate::sched`] layer together with
//!   admission control and graduated degradation.
//! * [`autoscaler`] — periodic LP re-solve from telemetry (§3.3.1
//!   "Resource Reallocation"), committed after two agreeing solutions.
//! * [`streaming`] — the managed Streaming Object: chunk granularity is
//!   load-dependent and runtime-controlled (§3.3.1 "Communication
//!   Granularity Management").
//! * [`controller`] — the live-mode control plane driving `exec` workers.

pub mod autoscaler;
pub mod controller;
pub mod router;
pub mod streaming;
pub mod telemetry;

pub use autoscaler::Autoscaler;
pub use router::{InstanceState, Router, RoutingPolicy};
// Queueing/scheduling moved into the shared `sched` layer; re-exported
// here so runtime-layer callers keep one import surface.
pub use crate::sched::queue::{QueueDiscipline, SlackPredictor};
pub use streaming::{StreamPolicy, StreamingMode};
pub use telemetry::Telemetry;
