//! The managed Streaming Object (§3.1) and its load-dependent chunk
//! policy (§3.3.1 "Communication Granularity Management").
//!
//! Streaming overlaps upstream compute with downstream prefill, but under
//! load it holds downstream slots while waiting for later chunks,
//! stalling the pipeline (Fig. 5: +11% at low load, −24% at high load
//! when unmanaged). Harmonia modulates the chunk *fraction* (chunk size /
//! total output) from real-time load against a pre-profiled table.

use std::sync::mpsc::{channel, Receiver, Sender};

/// How streaming is decided per hop.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StreamingMode {
    /// Never stream (downstream starts at upstream finish).
    Off,
    /// Always stream with a fixed chunk fraction (the unmanaged baseline
    /// of Fig. 5).
    FixedChunk(f64),
    /// Harmonia: chunk fraction chosen from current utilization.
    Managed,
}

/// Load-dependent chunk policy. Utilization is the downstream component's
/// occupancy in [0, 1+] (active+queued over capacity).
#[derive(Clone, Debug)]
pub struct StreamPolicy {
    /// Profiled (utilization, chunk_fraction) knots, ascending by
    /// utilization; interpolated at decision time.
    knots: Vec<(f64, f64)>,
}

impl Default for StreamPolicy {
    fn default() -> Self {
        // Offline-profiled shape: fine chunks while the pipeline is cold,
        // coarsen as the downstream saturates, stop streaming near
        // saturation (fraction 1.0 == no overlap, no stall).
        StreamPolicy {
            knots: vec![(0.0, 0.15), (0.5, 0.25), (0.75, 0.5), (0.9, 1.0)],
        }
    }
}

impl StreamPolicy {
    pub fn new(knots: Vec<(f64, f64)>) -> Self {
        assert!(knots.windows(2).all(|w| w[0].0 <= w[1].0));
        StreamPolicy { knots }
    }

    /// Chunk fraction for the current downstream utilization.
    pub fn chunk_fraction(&self, utilization: f64) -> f64 {
        let u = utilization.max(0.0);
        if self.knots.is_empty() {
            return 1.0;
        }
        if u <= self.knots[0].0 {
            return self.knots[0].1;
        }
        for w in self.knots.windows(2) {
            let (u0, f0) = w[0];
            let (u1, f1) = w[1];
            if u <= u1 {
                let t = (u - u0) / (u1 - u0).max(1e-9);
                return f0 + t * (f1 - f0);
            }
        }
        self.knots.last().unwrap().1
    }

    /// Resolve a mode + utilization into an effective chunk fraction
    /// (1.0 = no streaming).
    pub fn effective_fraction(&self, mode: StreamingMode, utilization: f64) -> f64 {
        match mode {
            StreamingMode::Off => 1.0,
            StreamingMode::FixedChunk(f) => f.clamp(0.01, 1.0),
            StreamingMode::Managed => self.chunk_fraction(utilization).clamp(0.01, 1.0),
        }
    }
}

/// Per-chunk fixed wire overhead (serialization + notify), seconds.
/// Matches the sub-millisecond gRPC/shared-memory costs the paper reports.
pub const CHUNK_OVERHEAD: f64 = 0.8e-3;

/// Per-chunk *busy* overhead on the consumer: each arriving chunk
/// preempts active decoding on the downstream instance (the paper's §2.2
/// finding that unmanaged streaming "can preempt active decoding and
/// introduce pipeline stalls"). Fine chunking at high load inflates the
/// consumer's occupancy by n_chunks × this value — the source of Fig. 5's
/// 24–36% high-load degradation.
pub const CHUNK_PREEMPT: f64 = 8.0e-3;

/// A managed streaming channel for the live path: producer writes chunks
/// at any granularity; the runtime re-chunks to the policy's granularity.
/// (The developer-facing API of Fig. 7 line 11.)
pub struct StreamObject<T> {
    tx: Sender<Vec<T>>,
    buffer: Vec<T>,
    chunk_len: usize,
}

impl<T> StreamObject<T> {
    /// Create with the runtime-chosen chunk length (items per chunk).
    pub fn new(chunk_len: usize) -> (Self, Receiver<Vec<T>>) {
        let (tx, rx) = channel();
        (StreamObject { tx, buffer: Vec::new(), chunk_len: chunk_len.max(1) }, rx)
    }

    /// Producer-side write; flushes whole chunks to the consumer.
    pub fn write(&mut self, item: T) {
        self.buffer.push(item);
        if self.buffer.len() >= self.chunk_len {
            // Swap in a pre-sized buffer: a steady-state producer never
            // re-grows its staging Vec from zero capacity per chunk.
            let chunk =
                std::mem::replace(&mut self.buffer, Vec::with_capacity(self.chunk_len));
            let _ = self.tx.send(chunk);
        }
    }

    /// Flush the tail and close the stream.
    pub fn finish(mut self) {
        if !self.buffer.is_empty() {
            let chunk = std::mem::take(&mut self.buffer);
            let _ = self.tx.send(chunk);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn managed_policy_monotone_in_load() {
        let p = StreamPolicy::default();
        let f_low = p.chunk_fraction(0.1);
        let f_mid = p.chunk_fraction(0.6);
        let f_high = p.chunk_fraction(0.95);
        assert!(f_low < f_mid && f_mid < f_high, "{f_low} {f_mid} {f_high}");
        assert_eq!(f_high, 1.0);
    }

    #[test]
    fn effective_fraction_modes() {
        let p = StreamPolicy::default();
        assert_eq!(p.effective_fraction(StreamingMode::Off, 0.2), 1.0);
        assert_eq!(p.effective_fraction(StreamingMode::FixedChunk(0.2), 0.9), 0.2);
        assert!(p.effective_fraction(StreamingMode::Managed, 0.0) < 0.2);
        assert_eq!(p.effective_fraction(StreamingMode::Managed, 2.0), 1.0);
    }

    #[test]
    fn interpolation_between_knots() {
        let p = StreamPolicy::new(vec![(0.0, 0.0), (1.0, 1.0)]);
        assert!((p.chunk_fraction(0.25) - 0.25).abs() < 1e-12);
        assert!((p.chunk_fraction(0.75) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stream_object_rechunks() {
        let (mut s, rx) = StreamObject::new(3);
        for i in 0..7 {
            s.write(i);
        }
        s.finish();
        let chunks: Vec<Vec<i32>> = rx.iter().collect();
        assert_eq!(chunks, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
    }

    #[test]
    fn stream_object_empty_finish() {
        let (s, rx) = StreamObject::<u8>::new(4);
        s.finish();
        assert!(rx.iter().next().is_none());
    }
}
