//! # Harmonia — an end-to-end RAG serving framework
//!
//! Rust reproduction of *"Harmonia: End-to-End RAG Serving Optimization"*
//! (a.k.a. *Patchwork: A Unified Framework for RAG Serving*): a three-layer
//! serving stack for Retrieval-Augmented-Generation pipelines.
//!
//! * [`spec`] — the **specification layer**: pipelines as component graphs
//!   with conditional branches, recursion, parallel fork/join dataflow
//!   (typed `Route`/`Fork` edges, `JoinSpec` barriers with `All` /
//!   racing `FirstK(k)` policies and state-merge semantics),
//!   amplification and constraints (stateful, resources, base
//!   instances), plus the reference RAG apps (Vanilla / Corrective /
//!   Self / Adaptive RAG) and the parallel-dataflow apps (hybrid
//!   dense ∥ web retrieval, multi-query expansion).
//! * [`alloc`] + [`lp`] — the **deployment layer**: the paper's
//!   generalized-network-flow resource-allocation LP (Fig. 8) solved with
//!   an in-crate two-phase simplex (Gurobi substitute); fork branches
//!   carry full flow (all provisioned) while joins scale inflow by
//!   1/branches, and latency models switch to critical-path over fork
//!   groups (`profile::graph_latency`).
//! * [`coordinator`] — the **runtime layer**: a centralized control plane
//!   with load/state-aware routing, deadline-aware (EDF + predicted slack)
//!   scheduling, telemetry-driven re-solving, and managed streaming with
//!   load-dependent chunk granularity.
//! * [`sched`] — the **scheduling layer** shared by the simulator and the
//!   live controller: deadline-aware queueing (`PrioQueue`,
//!   `SlackPredictor`), admission control (negative-slack shedding +
//!   backpressure), graduated degradation (top-k shrink / hop skip /
//!   iteration caps), unified behind `sched::ControlPlane`.
//! * [`runtime`] + [`exec`] — the **live data plane**: AOT-compiled XLA
//!   artifacts (JAX/Pallas, lowered at build time) executed via PJRT from
//!   worker threads; Python never runs on the request path. The generator
//!   serves with continuous (iteration-level) batching
//!   (`runtime::generator::InflightBatch` + `exec::worker::SteppedStage`):
//!   prefill-on-join into a free decode slot, retire-on-EOS, per-step
//!   token streaming — priced end-to-end by
//!   `profile::models::DecodeCostModel` so the DES, the LP priors, and
//!   admission slack agree on batched decode economics.
//! * [`retrieval`] — the ChromaDB substitute: an IVF index with the
//!   paper's `search_ef` knob, sharded scatter-gather search
//!   (`retrieval::sharded`) for independently scalable retrieval.
//! * [`cache`] — the request cache: exact + semantic memoization of the
//!   embed→retrieve prefix, so skewed (Zipfian) traffic short-circuits
//!   retrieval entirely on repeats; modeled end-to-end via
//!   `profile::models::cache_service_factor`.
//! * [`sim`] — a discrete-event **cluster simulator** that runs the same
//!   policy code against calibrated latency models to reproduce the
//!   paper-scale experiments (32 GPUs, 1024 req/s) on one machine; the
//!   LangChain-like and Haystack/Ray-like serving baselines live there as
//!   `sim::SystemKind::{LangChain, Haystack}`.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod alloc;
pub mod cache;
pub mod coordinator;
pub mod exec;
pub mod lp;
pub mod metrics;
pub mod profile;
pub mod retrieval;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod spec;
pub mod stats;
pub mod util;
pub mod workload;

/// Convenient re-exports for examples and benches.
pub mod prelude {
    pub use crate::alloc::{AllocationPlan, FlowProblem};
    pub use crate::spec::{apps, ComponentKind, PipelineGraph, ResourceKind};
    pub use crate::util::rng::Rng;
    pub use crate::workload::TraceConfig;
}
