//! Baselines live in sim::simrun (SystemKind::{LangChain, Haystack}).
