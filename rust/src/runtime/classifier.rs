//! Live query-complexity classifier (A-RAG): embedder → 3-way MLP
//! artifact. Classes: 0 simple (LLM-only), 1 standard (single-pass RAG),
//! 2 complex (iterative RAG).

use std::path::Path;

use anyhow::{Context, Result};

use super::embedder::Embedder;
use super::engine::{Engine, Tensor};

pub struct Classifier {
    embedder: Embedder,
    engine: Engine,
    batch: usize,
    dim: usize,
    n_classes: usize,
}

impl Classifier {
    pub fn new(dir: &Path) -> Result<Classifier> {
        let embedder = Embedder::new(dir)?;
        let engine = Engine::load(dir, Some(&["classifier"]))?;
        let spec = engine
            .manifest()
            .artifact("classifier")
            .context("classifier artifact missing")?;
        let batch = spec.inputs[0].shape[0];
        let dim = spec.inputs[0].shape[1];
        let n_classes = spec.outputs[0].shape[1];
        Ok(Classifier { embedder, engine, batch, dim, n_classes })
    }

    /// Classify a batch of query texts into complexity classes.
    pub fn classify_batch(&self, texts: &[&[u8]]) -> Result<Vec<u8>> {
        anyhow::ensure!(!texts.is_empty() && texts.len() <= self.batch);
        let embs = self.embedder.embed_batch(texts)?;
        let mut flat = Vec::with_capacity(self.batch * self.dim);
        for i in 0..self.batch {
            if i < embs.len() {
                flat.extend_from_slice(&embs[i]);
            } else {
                flat.extend(std::iter::repeat(0.0).take(self.dim));
            }
        }
        let out = self.engine.execute("classifier", &[Tensor::F32(flat)])?;
        let logits = out[0].as_f32()?;
        Ok((0..texts.len())
            .map(|i| {
                let row = &logits[i * self.n_classes..(i + 1) * self.n_classes];
                let mut best = 0;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                best as u8
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    #[test]
    fn classifies_deterministically_into_valid_classes() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let c = Classifier::new(&default_artifacts_dir()).unwrap();
        let texts: Vec<&[u8]> = vec![b"what is rust", b"explain quantum chromodynamics in detail"];
        let a = c.classify_batch(&texts).unwrap();
        let b = c.classify_batch(&texts).unwrap();
        assert_eq!(a, b);
        assert!(a.iter().all(|&cls| cls < 3));
    }
}
