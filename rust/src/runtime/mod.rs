//! The live data plane: AOT-compiled XLA artifacts executed via PJRT.
//!
//! Build-time Python (`python/compile/aot.py`) lowers the JAX/Pallas
//! models to HLO **text**; [`engine::Engine`] loads that text, compiles it
//! on the PJRT CPU client, and executes it — Python never runs on the
//! request path (the xla-crate pattern from /opt/xla-example/load_hlo).
//!
//! * [`manifest`] — parses `artifacts/manifest.txt` (shapes/dtypes/config).
//! * [`engine`] — artifact loading + execution.
//! * [`generator`] — batched LLM serving loop (prefill + decode with an
//!   explicit KV cache threaded through the artifact boundary).
//! * [`embedder`] / [`classifier`] / [`scorer`] — auxiliary models.

pub mod classifier;
pub mod embedder;
pub mod engine;
pub mod generator;
pub mod manifest;
pub mod scorer;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Dtype, Manifest, TensorSpec};

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("HARMONIA_ARTIFACTS") {
        return d.into();
    }
    "artifacts".into()
}

/// True if AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}
