//! Live retrieval scorer: the Pallas blocked-matmul artifact
//! (`retrieval_score`) scoring query embeddings against corpus shards.
//! The Rust IVF store picks candidates; this scores them MXU-style.

use std::path::Path;

use anyhow::{Context, Result};

use super::engine::{Engine, Tensor};

pub struct XlaScorer {
    engine: Engine,
    batch: usize,
    shard_n: usize,
    dim: usize,
}

impl XlaScorer {
    pub fn new(dir: &Path) -> Result<XlaScorer> {
        let engine = Engine::load(dir, Some(&["retrieval_score"]))?;
        let spec = engine
            .manifest()
            .artifact("retrieval_score")
            .context("retrieval_score artifact missing")?;
        let batch = spec.inputs[0].shape[0];
        let dim = spec.inputs[0].shape[1];
        let shard_n = spec.inputs[1].shape[0];
        Ok(XlaScorer { engine, batch, shard_n, dim })
    }

    pub fn shard_n(&self) -> usize {
        self.shard_n
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Score `queries` (≤ batch, each dim-long) against one shard
    /// (`shard_n × dim`, padded with zero rows if needed). Returns
    /// [n_queries][shard_n] scores.
    pub fn score_shard(&self, queries: &[&[f32]], shard: &[f32]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!queries.is_empty() && queries.len() <= self.batch);
        anyhow::ensure!(shard.len() == self.shard_n * self.dim, "shard must be padded");
        let mut q = Vec::with_capacity(self.batch * self.dim);
        for i in 0..self.batch {
            if i < queries.len() {
                anyhow::ensure!(queries[i].len() == self.dim);
                q.extend_from_slice(queries[i]);
            } else {
                q.extend(std::iter::repeat(0.0).take(self.dim));
            }
        }
        let out = self
            .engine
            .execute("retrieval_score", &[Tensor::F32(q), Tensor::F32(shard.to_vec())])?;
        let scores = out[0].as_f32()?;
        Ok((0..queries.len())
            .map(|i| scores[i * self.shard_n..(i + 1) * self.shard_n].to_vec())
            .collect())
    }

    /// Top-k over a candidate set using shard-batched XLA scoring.
    /// `vectors(i)` returns the embedding of candidate i.
    pub fn topk_candidates(
        &self,
        query: &[f32],
        candidates: &[usize],
        vectors: impl Fn(usize) -> Vec<f32>,
        k: usize,
    ) -> Result<Vec<(usize, f32)>> {
        let mut results: Vec<(usize, f32)> = Vec::with_capacity(candidates.len());
        for chunk in candidates.chunks(self.shard_n) {
            let mut shard = Vec::with_capacity(self.shard_n * self.dim);
            for &c in chunk {
                shard.extend(vectors(c));
            }
            shard.resize(self.shard_n * self.dim, 0.0);
            let scores = self.score_shard(&[query], &shard)?;
            for (j, &c) in chunk.iter().enumerate() {
                results.push((c, scores[0][j]));
            }
        }
        results.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        results.truncate(k);
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    #[test]
    fn scores_match_cpu_dot_product() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let s = XlaScorer::new(&default_artifacts_dir()).unwrap();
        let dim = s.dim();
        let mut rng = crate::util::rng::Rng::new(0);
        let q: Vec<f32> = (0..dim).map(|_| rng.f32() - 0.5).collect();
        let shard: Vec<f32> = (0..s.shard_n() * dim).map(|_| rng.f32() - 0.5).collect();
        let got = s.score_shard(&[&q], &shard).unwrap();
        for row in 0..8 {
            let expect: f32 = (0..dim).map(|d| q[d] * shard[row * dim + d]).sum();
            assert!(
                (got[0][row] - expect).abs() < 1e-3,
                "row {row}: {} vs {expect}",
                got[0][row]
            );
        }
    }

    #[test]
    fn topk_orders_by_score() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let s = XlaScorer::new(&default_artifacts_dir()).unwrap();
        let dim = s.dim();
        // Candidate i has embedding e_i = i/n in first coordinate.
        let n = 50;
        let q = {
            let mut v = vec![0.0f32; dim];
            v[0] = 1.0;
            v
        };
        let cands: Vec<usize> = (0..n).collect();
        let top = s
            .topk_candidates(&q, &cands, |i| {
                let mut v = vec![0.0f32; dim];
                v[0] = i as f32 / n as f32;
                v
            }, 5)
            .unwrap();
        assert_eq!(top.len(), 5);
        assert_eq!(top[0].0, n - 1);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
