//! Live embedder: byte text → unit-norm embedding via the AOT artifact.
//! Used for corpus indexing (offline) and query embedding (request path).

use std::path::Path;

use anyhow::{Context, Result};

use super::engine::{Engine, Tensor};
use super::generator::tokenize;

pub struct Embedder {
    engine: Engine,
    batch: usize,
    seq: usize,
    dim: usize,
}

impl Embedder {
    pub fn new(dir: &Path) -> Result<Embedder> {
        let engine = Engine::load(dir, Some(&["embedder"]))?;
        let spec = engine
            .manifest()
            .artifact("embedder")
            .context("embedder artifact missing")?;
        let batch = spec.inputs[0].shape[0];
        let seq = spec.inputs[0].shape[1];
        let dim = spec.outputs[0].shape[1];
        Ok(Embedder { engine, batch, seq, dim })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Embed up to `batch` texts (padded internally). Returns one vector
    /// per input text.
    pub fn embed_batch(&self, texts: &[&[u8]]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!texts.is_empty() && texts.len() <= self.batch);
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut lengths = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            let text: &[u8] = if i < texts.len() { texts[i] } else { b"." };
            let (t, l) = tokenize(text, self.seq);
            tokens.extend_from_slice(&t);
            lengths.push(l);
        }
        let out = self
            .engine
            .execute("embedder", &[Tensor::I32(tokens), Tensor::I32(lengths)])?;
        let emb = out[0].as_f32()?;
        Ok(texts
            .iter()
            .enumerate()
            .map(|(i, _)| emb[i * self.dim..(i + 1) * self.dim].to_vec())
            .collect())
    }

    /// Embed an arbitrary number of texts in batches.
    pub fn embed_all(&self, texts: &[Vec<u8>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(self.batch) {
            let refs: Vec<&[u8]> = chunk.iter().map(|t| t.as_slice()).collect();
            out.extend(self.embed_batch(&refs)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    #[test]
    fn embeddings_unit_norm_and_padding_independent() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let e = Embedder::new(&default_artifacts_dir()).unwrap();
        let texts: Vec<&[u8]> = vec![b"alpha bravo", b"charlie delta"];
        let full = e.embed_batch(&texts).unwrap();
        for v in &full {
            let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-3);
        }
        // A text's embedding must not depend on its batch-mates.
        let solo = e.embed_batch(&[b"alpha bravo"]).unwrap();
        for (a, b) in solo[0].iter().zip(&full[0]) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn embed_all_chunks() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let e = Embedder::new(&default_artifacts_dir()).unwrap();
        let texts: Vec<Vec<u8>> = (0..19)
            .map(|i| format!("passage number {i}").into_bytes())
            .collect();
        let embs = e.embed_all(&texts).unwrap();
        assert_eq!(embs.len(), 19);
        assert_eq!(embs[0].len(), e.dim());
    }
}
