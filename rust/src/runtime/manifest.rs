//! Parser for `artifacts/manifest.txt` — the line-based artifact
//! description emitted by `python/compile/aot.py`. Shapes and model
//! config cross the Python↔Rust boundary exactly once, here.
//!
//! Format:
//! ```text
//! config vocab 256
//! artifact generator_decode_b8
//! path generator_decode_b8.hlo.txt
//! input kv f32 2,2,8,4,128,16
//! output logits f32 8,256
//! end
//! ```

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of a tensor at the artifact boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// Named, shaped tensor.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO-text filename, relative to the artifacts dir.
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest: model config + artifact list.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub config: HashMap<String, String>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            let ctx = || format!("manifest line {}: '{line}'", lineno + 1);
            match tag {
                "config" => {
                    if rest.len() != 2 {
                        bail!("{}: config needs key value", ctx());
                    }
                    m.config.insert(rest[0].into(), rest[1].into());
                }
                "artifact" => {
                    if cur.is_some() {
                        bail!("{}: nested artifact", ctx());
                    }
                    cur = Some(ArtifactSpec {
                        name: rest.first().context("artifact needs name")?.to_string(),
                        path: String::new(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "path" => {
                    cur.as_mut().with_context(ctx)?.path =
                        rest.first().context("path needs value")?.to_string();
                }
                "input" | "output" => {
                    if rest.len() != 3 {
                        bail!("{}: need name dtype shape", ctx());
                    }
                    let spec = TensorSpec {
                        name: rest[0].into(),
                        dtype: Dtype::parse(rest[1])?,
                        shape: rest[2]
                            .split(',')
                            .filter(|s| !s.is_empty())
                            .map(|s| s.parse::<usize>().with_context(ctx))
                            .collect::<Result<Vec<_>>>()?,
                    };
                    let a = cur.as_mut().with_context(ctx)?;
                    if tag == "input" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "end" => {
                    let a = cur.take().with_context(ctx)?;
                    if a.path.is_empty() {
                        bail!("{}: artifact '{}' missing path", ctx(), a.name);
                    }
                    m.artifacts.push(a);
                }
                other => bail!("{}: unknown tag '{other}'", ctx()),
            }
        }
        if cur.is_some() {
            bail!("manifest ended inside an artifact block");
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let p = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Integer config value (vocab, d_model, …).
    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .with_context(|| format!("missing config '{key}'"))?
            .parse()
            .with_context(|| format!("config '{key}' not an integer"))
    }

    /// The compiled generator batch sizes, ascending.
    pub fn gen_batch_sizes(&self) -> Result<Vec<usize>> {
        let s = self
            .config
            .get("gen_batch_sizes")
            .context("missing gen_batch_sizes")?;
        let mut v = s
            .split(',')
            .map(|x| x.parse::<usize>().context("bad batch size"))
            .collect::<Result<Vec<_>>>()?;
        v.sort_unstable();
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
config vocab 256
config gen_batch_sizes 4,1,8,2
artifact embedder
path embedder.hlo.txt
input tokens i32 8,64
input length i32 8
output emb f32 8,64
end
artifact classifier
path classifier.hlo.txt
input emb f32 8,64
output logits f32 8,3
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config_usize("vocab").unwrap(), 256);
        assert_eq!(m.artifacts.len(), 2);
        let e = m.artifact("embedder").unwrap();
        assert_eq!(e.path, "embedder.hlo.txt");
        assert_eq!(e.inputs[0].shape, vec![8, 64]);
        assert_eq!(e.inputs[0].dtype, Dtype::I32);
        assert_eq!(e.outputs[0].dtype, Dtype::F32);
        assert_eq!(e.inputs[0].elements(), 512);
    }

    #[test]
    fn batch_sizes_sorted() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.gen_batch_sizes().unwrap(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn rejects_unknown_tag() {
        assert!(Manifest::parse("bogus x y\n").is_err());
    }

    #[test]
    fn rejects_unterminated_artifact() {
        assert!(Manifest::parse("artifact a\npath p\n").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = "artifact a\npath p\ninput x f64 2\nend\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn rejects_missing_path() {
        assert!(Manifest::parse("artifact a\nend\n").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifact("generator_decode_b8").is_some());
        assert_eq!(m.config_usize("vocab").unwrap(), 256);
    }
}
