//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them with shape/dtype checking against the manifest.
//!
//! HLO *text* (not serialized protos) is the interchange format — see
//! /opt/xla-example/README.md: jax ≥ 0.5 emits 64-bit instruction ids the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Dtype, Manifest};

/// A host tensor crossing the artifact boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(_) => Dtype::F32,
            Tensor::I32(_) => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            Tensor::I32(_) => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            Tensor::F32(_) => bail!("expected i32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(v) => Ok(v),
            Tensor::I32(_) => bail!("expected f32 tensor"),
        }
    }
}

/// One compiled artifact.
struct Compiled {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine owns a PJRT client and the compiled executables.
///
/// PJRT handles are not `Send`; each worker thread constructs its own
/// `Engine` (compilation of these small modules takes tens of ms).
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, Compiled>,
    dir: PathBuf,
}

impl Engine {
    /// Load the manifest and compile the named artifacts (None = all).
    pub fn load(dir: &Path, names: Option<&[&str]>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut compiled = HashMap::new();
        for spec in &manifest.artifacts {
            if let Some(ns) = names {
                if !ns.contains(&spec.name.as_str()) {
                    continue;
                }
            }
            let path = dir.join(&spec.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            compiled.insert(spec.name.clone(), Compiled { spec: spec.clone(), exe });
        }
        Ok(Engine { client, manifest, compiled, dir: dir.to_path_buf() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Lazily compile one more artifact (used when a batcher needs a new
    /// bucket size at runtime).
    pub fn ensure(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("unknown artifact '{name}'"))?
            .clone();
        let path = self.dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8")?)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), Compiled { spec, exe });
        Ok(())
    }

    /// Execute with raw literals (hot-path variant: no host-vector
    /// round-trips — callers keep large state like the KV cache as
    /// `xla::Literal` across steps). Outputs in manifest order.
    pub fn execute_literals(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let c = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        if inputs.len() != c.spec.inputs.len() {
            bail!("'{name}' expects {} inputs, got {}", c.spec.inputs.len(), inputs.len());
        }
        let result = c.exe.execute::<xla::Literal>(inputs)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        let parts = first.to_tuple()?;
        if parts.len() != c.spec.outputs.len() {
            bail!("'{name}' returned {} outputs, manifest says {}", parts.len(), c.spec.outputs.len());
        }
        Ok(parts)
    }

    /// Build a shape-checked input literal for an artifact parameter.
    pub fn input_literal(&self, name: &str, index: usize, t: &Tensor) -> Result<xla::Literal> {
        let c = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let spec = c.spec.inputs.get(index).context("input index out of range")?;
        anyhow::ensure!(t.dtype() == spec.dtype, "'{name}' input {index}: dtype mismatch");
        anyhow::ensure!(
            t.len() == spec.elements(),
            "'{name}' input {index}: {} elements, expected {}",
            t.len(),
            spec.elements()
        );
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match t {
            Tensor::F32(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(if dims.len() == 1 { lit } else { lit.reshape(&dims)? })
    }

    /// Execute an artifact with host tensors; validates shapes/dtypes
    /// against the manifest and returns outputs in manifest order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let c = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        if inputs.len() != c.spec.inputs.len() {
            bail!(
                "'{name}' expects {} inputs, got {}",
                c.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&c.spec.inputs) {
            if t.dtype() != spec.dtype {
                bail!("'{name}' input '{}': dtype mismatch", spec.name);
            }
            if t.len() != spec.elements() {
                bail!(
                    "'{name}' input '{}': {} elements, expected {:?}={}",
                    spec.name,
                    t.len(),
                    spec.shape,
                    spec.elements()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match t {
                Tensor::F32(v) => xla::Literal::vec1(v),
                Tensor::I32(v) => xla::Literal::vec1(v),
            };
            // 0-d and 1-d shapes can skip the reshape.
            let lit = if dims.len() == 1 { lit } else { lit.reshape(&dims)? };
            literals.push(lit);
        }
        let result = c.exe.execute::<xla::Literal>(&literals)?;
        // jax lowering uses return_tuple=True: one buffer holding a tuple.
        let first = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        let parts = first.to_tuple()?;
        if parts.len() != c.spec.outputs.len() {
            bail!(
                "'{name}' returned {} outputs, manifest says {}",
                parts.len(),
                c.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&c.spec.outputs) {
            let t = match spec.dtype {
                Dtype::F32 => Tensor::F32(lit.to_vec::<f32>()?),
                Dtype::I32 => Tensor::I32(lit.to_vec::<i32>()?),
            };
            if t.len() != spec.elements() {
                bail!("'{name}' output '{}': unexpected element count", spec.name);
            }
            out.push(t);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    fn engine(names: &[&str]) -> Option<Engine> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::load(&default_artifacts_dir(), Some(names)).unwrap())
    }

    #[test]
    fn embedder_roundtrip() {
        let Some(e) = engine(&["embedder"]) else { return };
        let spec = e.manifest().artifact("embedder").unwrap().clone();
        let (b, s) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let tokens: Vec<i32> = (0..b * s).map(|i| (i % 200 + 1) as i32).collect();
        let lengths: Vec<i32> = (0..b).map(|i| (8 + i) as i32).collect();
        let out = e
            .execute("embedder", &[Tensor::I32(tokens), Tensor::I32(lengths)])
            .unwrap();
        let emb = out[0].as_f32().unwrap();
        assert_eq!(emb.len(), b * 64);
        // Rows are unit-norm (model invariant).
        for r in 0..b {
            let norm: f32 = emb[r * 64..(r + 1) * 64].iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3, "row {r} norm {norm}");
        }
    }

    #[test]
    fn execute_validates_shapes() {
        let Some(e) = engine(&["classifier"]) else { return };
        // Wrong element count must error, not crash.
        let r = e.execute("classifier", &[Tensor::F32(vec![0.0; 7])]);
        assert!(r.is_err());
        // Wrong dtype must error.
        let r = e.execute("classifier", &[Tensor::I32(vec![0; 8 * 64])]);
        assert!(r.is_err());
    }

    #[test]
    fn classifier_runs() {
        let Some(e) = engine(&["classifier"]) else { return };
        let emb = vec![0.1f32; 8 * 64];
        let out = e.execute("classifier", &[Tensor::F32(emb)]).unwrap();
        let logits = out[0].as_f32().unwrap();
        assert_eq!(logits.len(), 8 * 3);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(e) = engine(&["classifier"]) else { return };
        assert!(e.execute("nope", &[]).is_err());
    }
}
