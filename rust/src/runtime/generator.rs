//! Batched LLM serving loop over the AOT generator artifacts.
//!
//! vLLM-style bucketed batching: the generator is compiled for batch sizes
//! {1,2,4,8}; a request batch is padded up to the nearest bucket. The KV
//! cache is threaded explicitly through the artifact boundary
//! (`prefill → (logits, kv)`, `decode(kv, token, pos) → (logits, kv)`), so
//! the Rust side owns scheduling while XLA owns math.
//!
//! Tokens are bytes (vocab 256); token 0 is PAD/EOS.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::engine::{Engine, Tensor};

/// EOS/PAD token id.
pub const EOS: i32 = 0;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// Greedy if None, else softmax temperature sampling with this seed.
    pub temperature: Option<(f64, u64)>,
}

impl GenRequest {
    pub fn greedy(prompt: &[u8], max_new_tokens: usize) -> Self {
        GenRequest { prompt: prompt.to_vec(), max_new_tokens, temperature: None }
    }
}

/// Result of one request.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub output: Vec<u8>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
}

/// Timing of one batch execution (for telemetry / EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchTiming {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    pub batch_size: usize,
}

/// Byte-level tokenizer: text bytes are tokens; 0 is reserved.
pub fn tokenize(text: &[u8], max_len: usize) -> (Vec<i32>, i32) {
    let n = text.len().min(max_len).max(1);
    let mut toks: Vec<i32> = text[..text.len().min(max_len)]
        .iter()
        .map(|&b| if b == 0 { 1 } else { b as i32 })
        .collect();
    if toks.is_empty() {
        toks.push(1); // empty prompt: single dummy token
    }
    toks.resize(max_len, 0);
    (toks, n as i32)
}

/// The batched generator.
pub struct Generator {
    engine: Engine,
    batch_sizes: Vec<usize>,
    max_seq: usize,
    vocab: usize,
    kv_elems_per_b: usize,
}

impl Generator {
    pub fn new(dir: &Path) -> Result<Generator> {
        // Compile every prefill/decode bucket.
        let manifest = super::manifest::Manifest::load(dir)?;
        let batch_sizes = manifest.gen_batch_sizes()?;
        let names: Vec<String> = batch_sizes
            .iter()
            .flat_map(|b| {
                vec![format!("generator_prefill_b{b}"), format!("generator_decode_b{b}")]
            })
            .collect();
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let engine = Engine::load(dir, Some(&name_refs))?;
        let max_seq = manifest.config_usize("max_seq")?;
        let vocab = manifest.config_usize("vocab")?;
        let l = manifest.config_usize("n_layers")?;
        let h = manifest.config_usize("n_heads")?;
        let dh = manifest.config_usize("d_head")?;
        Ok(Generator {
            engine,
            batch_sizes,
            max_seq,
            vocab,
            kv_elems_per_b: l * 2 * h * max_seq * dh,
        })
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Smallest compiled bucket that fits `n` requests.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .with_context(|| format!("no batch bucket fits {n} requests (max {:?})", self.batch_sizes.last()))
    }

    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().unwrap()
    }

    /// Generate for a batch of requests (≤ max bucket). `on_token` is the
    /// streaming hook: called with (request index, byte) as tokens decode.
    pub fn generate_batch(
        &self,
        reqs: &[GenRequest],
        mut on_token: impl FnMut(usize, u8),
    ) -> Result<(Vec<GenResult>, BatchTiming)> {
        if reqs.is_empty() {
            bail!("empty batch");
        }
        let b = self.bucket_for(reqs.len())?;
        let prefill = format!("generator_prefill_b{b}");
        let decode = format!("generator_decode_b{b}");

        // Build padded token matrix.
        let mut tokens = Vec::with_capacity(b * self.max_seq);
        let mut lengths = Vec::with_capacity(b);
        for i in 0..b {
            let prompt: &[u8] = if i < reqs.len() { &reqs[i].prompt } else { b"." };
            // Leave room for generation.
            let budget = self.max_seq.saturating_sub(
                reqs.get(i).map_or(1, |r| r.max_new_tokens).min(self.max_seq / 2),
            );
            let (t, l) = tokenize(prompt, self.max_seq);
            let l = (l as usize).min(budget.max(1)) as i32;
            tokens.extend_from_slice(&t);
            lengths.push(l);
        }

        let t0 = Instant::now();
        // Hot path (§Perf): keep the KV cache as an xla::Literal across
        // steps — the Tensor round-trip copied the (multi-MB) cache three
        // times per decoded token.
        let toks_lit = self.engine.input_literal(&prefill, 0, &Tensor::I32(tokens))?;
        let len_lit = self.engine.input_literal(&prefill, 1, &Tensor::I32(lengths.clone()))?;
        let mut out = self.engine.execute_literals(&prefill, &[toks_lit, len_lit])?;
        let prefill_secs = t0.elapsed().as_secs_f64();
        let mut kv = out.pop().context("missing kv output")?;
        let mut logits: Vec<f32> = out.pop().context("missing logits")?.to_vec()?;
        debug_assert_eq!(kv.size_bytes(), self.kv_elems_per_b * b * 4);

        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); reqs.len()];
        let mut done: Vec<bool> = (0..reqs.len()).map(|_| false).collect();
        let mut pos: Vec<i32> = lengths.clone();
        let max_new = reqs.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
        let mut steps = 0usize;
        let t1 = Instant::now();
        for step in 0..max_new {
            // Sample next token per live slot.
            let mut next: Vec<i32> = Vec::with_capacity(b);
            for slot in 0..b {
                let row = &logits[slot * self.vocab..(slot + 1) * self.vocab];
                let tok = if slot >= reqs.len() || done[slot] {
                    EOS
                } else {
                    sample(row, reqs[slot].temperature, step)
                };
                if slot < reqs.len() && !done[slot] {
                    if tok == EOS || outputs[slot].len() + 1 >= reqs[slot].max_new_tokens {
                        done[slot] = true;
                    }
                    if tok != EOS {
                        outputs[slot].push(tok as u8);
                        on_token(slot, tok as u8);
                    }
                }
                next.push(tok);
            }
            if done.iter().all(|&d| d) {
                break;
            }
            // Positions: the sampled token is written at current pos.
            let write_pos: Vec<i32> = pos
                .iter()
                .map(|&p| p.min(self.max_seq as i32 - 1))
                .collect();
            let next_lit = self.engine.input_literal(&decode, 1, &Tensor::I32(next))?;
            let pos_lit = self.engine.input_literal(&decode, 2, &Tensor::I32(write_pos))?;
            let mut out = self.engine.execute_literals(&decode, &[kv, next_lit, pos_lit])?;
            kv = out.pop().context("missing kv output")?;
            logits = out.pop().context("missing logits")?.to_vec()?;
            for p in pos.iter_mut() {
                *p = (*p + 1).min(self.max_seq as i32 - 1);
            }
            steps += 1;
        }
        let decode_secs = t1.elapsed().as_secs_f64();

        let results = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| GenResult {
                output: outputs[i].clone(),
                prompt_tokens: r.prompt.len().min(self.max_seq),
                generated_tokens: outputs[i].len(),
            })
            .collect();
        Ok((results, BatchTiming { prefill_secs, decode_secs, decode_steps: steps, batch_size: b }))
    }

    /// Single-token verdict (grader / critic): prefill and reduce the
    /// next-token distribution to a boolean. With the synthetic (randomly
    /// initialized) LM the absolute 'Y'/'N' logit margin is dominated by
    /// output-projection bias, so the verdict is derived from the argmax
    /// token's parity — deterministic per input, varies across inputs,
    /// which is what downstream control flow needs.
    pub fn verdict(&self, text: &[u8]) -> Result<bool> {
        let b = self.bucket_for(1)?;
        let prefill = format!("generator_prefill_b{b}");
        let mut tokens = Vec::with_capacity(b * self.max_seq);
        let mut lengths = Vec::with_capacity(b);
        for i in 0..b {
            let prompt: &[u8] = if i == 0 { text } else { b"." };
            let (t, l) = tokenize(prompt, self.max_seq);
            tokens.extend_from_slice(&t);
            lengths.push(l);
        }
        let out = self
            .engine
            .execute(&prefill, &[Tensor::I32(tokens), Tensor::I32(lengths)])?;
        let logits = out[0].as_f32()?;
        Ok(argmax(&logits[..self.vocab]) % 2 == 0)
    }
}

/// Greedy argmax or temperature sampling over a logit row.
fn sample(row: &[f32], temperature: Option<(f64, u64)>, step: usize) -> i32 {
    match temperature {
        None => argmax(row) as i32,
        Some((temp, seed)) => {
            let mut rng = crate::util::rng::Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37));
            let inv = 1.0 / temp.max(1e-3);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> =
                row.iter().map(|&l| (((l - m) as f64) * inv).exp()).collect();
            rng.weighted(&weights) as i32
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    fn generator() -> Option<Generator> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Generator::new(&default_artifacts_dir()).unwrap())
    }

    #[test]
    fn tokenize_pads_and_counts() {
        let (t, l) = tokenize(b"hi", 8);
        assert_eq!(l, 2);
        assert_eq!(t, vec![104, 105, 0, 0, 0, 0, 0, 0]);
        let (t, l) = tokenize(b"", 4);
        assert_eq!(l, 1);
        assert_eq!(t[0], 1);
    }

    #[test]
    fn tokenize_truncates() {
        let long = vec![65u8; 300];
        let (t, l) = tokenize(&long, 128);
        assert_eq!(t.len(), 128);
        assert_eq!(l, 128);
    }

    #[test]
    fn bucket_selection() {
        let Some(g) = generator() else { return };
        assert_eq!(g.bucket_for(1).unwrap(), 1);
        assert_eq!(g.bucket_for(3).unwrap(), 4);
        assert_eq!(g.bucket_for(8).unwrap(), 8);
        assert!(g.bucket_for(9).is_err());
    }

    #[test]
    fn generates_deterministic_greedy_output() {
        let Some(g) = generator() else { return };
        let req = GenRequest::greedy(b"What is the capital of France?", 8);
        let (r1, t1) = g.generate_batch(std::slice::from_ref(&req), |_, _| {}).unwrap();
        let (r2, _) = g.generate_batch(&[req], |_, _| {}).unwrap();
        assert_eq!(r1[0].output, r2[0].output, "greedy must be deterministic");
        assert!(r1[0].generated_tokens <= 8);
        assert!(t1.prefill_secs > 0.0);
        assert_eq!(t1.batch_size, 1);
    }

    #[test]
    fn batch_matches_single_request() {
        // Batching must not change a request's greedy output (prefill pads
        // other slots; attention is masked per-row).
        let Some(g) = generator() else { return };
        let a = GenRequest::greedy(b"hello world", 6);
        let bq = GenRequest::greedy(b"completely different prompt!", 6);
        let (solo, _) = g.generate_batch(std::slice::from_ref(&a), |_, _| {}).unwrap();
        let (duo, timing) = g.generate_batch(&[a, bq], |_, _| {}).unwrap();
        assert_eq!(solo[0].output, duo[0].output);
        assert_eq!(timing.batch_size, 2);
    }

    #[test]
    fn streaming_callback_sees_every_token() {
        let Some(g) = generator() else { return };
        let req = GenRequest::greedy(b"stream me", 6);
        let mut streamed = Vec::new();
        let (res, _) = g
            .generate_batch(&[req], |slot, byte| {
                assert_eq!(slot, 0);
                streamed.push(byte);
            })
            .unwrap();
        assert_eq!(streamed, res[0].output);
    }

    #[test]
    fn verdict_is_deterministic_and_input_sensitive() {
        let Some(g) = generator() else { return };
        let a = g.verdict(b"Does retrieved doc have relevant info? doc: Paris is in France").unwrap();
        let a2 = g.verdict(b"Does retrieved doc have relevant info? doc: Paris is in France").unwrap();
        assert_eq!(a, a2);
        // Across many inputs both verdicts occur (not a constant function).
        let mut seen = std::collections::HashSet::new();
        for i in 0..8 {
            let text = format!("judge this doc number {i} with content xyz{i}");
            seen.insert(g.verdict(text.as_bytes()).unwrap());
        }
        assert_eq!(seen.len(), 2, "verdict should vary with input");
    }
}
