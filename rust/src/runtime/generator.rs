//! Batched LLM serving loop over the AOT generator artifacts.
//!
//! vLLM-style bucketed batching: the generator is compiled for batch sizes
//! {1,2,4,8}; a request batch is padded up to the nearest bucket. The KV
//! cache is threaded explicitly through the artifact boundary
//! (`prefill → (logits, kv)`, `decode(kv, token, pos) → (logits, kv)`), so
//! the Rust side owns scheduling while XLA owns math.
//!
//! **Prefill/decode split.** Prefill and decode are separate compiled
//! artifacts (`generator_prefill_b{b}` / `generator_decode_b{b}`) joined
//! only by the host-side KV tensor — exactly the seam a disaggregated
//! deployment cuts. The live stepped stage already runs them as distinct
//! phases ([`Generator::inflight_admit`] = the prefill stage,
//! [`Generator::inflight_step`] = the decode stage, wired through the
//! controller's worker loop), and [`BatchTiming`] /
//! [`InflightDone::service_secs`] attribute their costs separately. This
//! process keeps both phases on one engine (collocated); moving the KV
//! tensor across a pool boundary instead is what
//! `SimConfig::gen_placement = Disaggregated` models, with
//! `profile::models::KvTransferModel` pricing the handoff this tensor
//! would pay.
//!
//! Tokens are bytes (vocab 256); token 0 is PAD/EOS.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::engine::{Engine, Tensor};

/// EOS/PAD token id.
pub const EOS: i32 = 0;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// Greedy if None, else softmax temperature sampling with this seed.
    pub temperature: Option<(f64, u64)>,
}

impl GenRequest {
    pub fn greedy(prompt: &[u8], max_new_tokens: usize) -> Self {
        GenRequest { prompt: prompt.to_vec(), max_new_tokens, temperature: None }
    }
}

/// Result of one request.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub output: Vec<u8>,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
}

/// Timing of one batch execution (for telemetry / EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchTiming {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    pub batch_size: usize,
}

/// Per-slot state of one in-flight request (continuous batching).
struct InflightSlot {
    /// Next KV write position.
    pos: i32,
    /// Decode steps this slot has participated in.
    steps: usize,
    out: Vec<u8>,
    max_new: usize,
    temperature: Option<(f64, u64)>,
    prompt_tokens: usize,
    /// Attributed GPU seconds: prefill + this slot's share of each
    /// decode step it decoded in (step wall time / step occupancy).
    service_secs: f64,
}

/// An iteration-level (continuous) batch: per-slot KV state at the
/// largest compiled bucket, with requests admitted into free slots
/// between decode steps ([`Generator::inflight_admit`], prefill-on-join)
/// and retired the step they emit EOS or hit their token cap
/// ([`Generator::inflight_step`]).
///
/// The KV cache is held host-side so a single-request prefill can be
/// spliced into one slot's slabs without disturbing its neighbors; each
/// decode step round-trips it through the artifact boundary. That trades
/// the static path's literal-resident KV optimization for slot-level
/// admission — a device-side KV scatter would need a new artifact. If
/// [`Generator::inflight_step`] returns an error the batch state is
/// poisoned; discard it and start a fresh one with
/// [`Generator::begin_inflight`].
pub struct InflightBatch {
    bucket: usize,
    /// Host KV cache [L, 2, bucket, H, S, Dh].
    kv: Vec<f32>,
    /// Last logits per slot [bucket, vocab].
    logits: Vec<f32>,
    slots: Vec<Option<InflightSlot>>,
    /// Set when a decode execution failed: the KV state is lost, so the
    /// survivors can never produce another token. The failing step still
    /// returns the requests that retired *before* the decode ran (their
    /// outputs were complete); further steps error and admissions are
    /// refused until the batch is discarded.
    poisoned: Option<String>,
}

impl InflightBatch {
    /// Occupied slots.
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Slots a new request could join (0 once the batch is poisoned).
    pub fn free_slots(&self) -> usize {
        if self.poisoned.is_some() {
            return 0;
        }
        self.bucket - self.occupancy()
    }

    /// The decode-failure message, if a step has poisoned this batch.
    pub fn poisoned(&self) -> Option<&str> {
        self.poisoned.as_deref()
    }

    /// Compiled bucket size this batch decodes at.
    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Drop every in-flight request (shutdown / after a step error),
    /// returning the freed slot indices.
    pub fn clear(&mut self) -> Vec<usize> {
        let mut freed = Vec::new();
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.take().is_some() {
                freed.push(i);
            }
        }
        freed
    }
}

/// A request retired from an [`InflightBatch`] (EOS or token cap).
pub struct InflightDone {
    /// The slot it occupied (now free).
    pub slot: usize,
    pub result: GenResult,
    /// Per-slot attributed service: prefill + decode-step shares.
    pub service_secs: f64,
}

/// Byte-level tokenizer: text bytes are tokens; 0 is reserved.
pub fn tokenize(text: &[u8], max_len: usize) -> (Vec<i32>, i32) {
    let n = text.len().min(max_len).max(1);
    let mut toks: Vec<i32> = text[..text.len().min(max_len)]
        .iter()
        .map(|&b| if b == 0 { 1 } else { b as i32 })
        .collect();
    if toks.is_empty() {
        toks.push(1); // empty prompt: single dummy token
    }
    toks.resize(max_len, 0);
    (toks, n as i32)
}

/// The batched generator.
pub struct Generator {
    engine: Engine,
    batch_sizes: Vec<usize>,
    max_seq: usize,
    vocab: usize,
    kv_elems_per_b: usize,
    /// KV cache layout [L, 2, B, H, S, Dh]: `kv_planes` = L·2 outer
    /// planes, each holding `B` contiguous per-slot slabs of `kv_slab`
    /// = H·S·Dh elements — what [`InflightBatch`] splices per slot.
    kv_planes: usize,
    kv_slab: usize,
}

impl Generator {
    pub fn new(dir: &Path) -> Result<Generator> {
        // Compile every prefill/decode bucket.
        let manifest = super::manifest::Manifest::load(dir)?;
        let batch_sizes = manifest.gen_batch_sizes()?;
        let names: Vec<String> = batch_sizes
            .iter()
            .flat_map(|b| {
                vec![format!("generator_prefill_b{b}"), format!("generator_decode_b{b}")]
            })
            .collect();
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let engine = Engine::load(dir, Some(&name_refs))?;
        let max_seq = manifest.config_usize("max_seq")?;
        let vocab = manifest.config_usize("vocab")?;
        let l = manifest.config_usize("n_layers")?;
        let h = manifest.config_usize("n_heads")?;
        let dh = manifest.config_usize("d_head")?;
        Ok(Generator {
            engine,
            batch_sizes,
            max_seq,
            vocab,
            kv_elems_per_b: l * 2 * h * max_seq * dh,
            kv_planes: l * 2,
            kv_slab: h * max_seq * dh,
        })
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Smallest compiled bucket that fits `n` requests.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.batch_sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .with_context(|| format!("no batch bucket fits {n} requests (max {:?})", self.batch_sizes.last()))
    }

    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().unwrap()
    }

    /// Generate for a batch of requests (≤ max bucket). `on_token` is the
    /// streaming hook: called with (request index, byte) as tokens decode.
    pub fn generate_batch(
        &self,
        reqs: &[GenRequest],
        mut on_token: impl FnMut(usize, u8),
    ) -> Result<(Vec<GenResult>, BatchTiming)> {
        if reqs.is_empty() {
            bail!("empty batch");
        }
        let b = self.bucket_for(reqs.len())?;
        let prefill = format!("generator_prefill_b{b}");
        let decode = format!("generator_decode_b{b}");

        // Build padded token matrix.
        let mut tokens = Vec::with_capacity(b * self.max_seq);
        let mut lengths = Vec::with_capacity(b);
        for i in 0..b {
            let prompt: &[u8] = if i < reqs.len() { &reqs[i].prompt } else { b"." };
            // Leave room for generation.
            let budget = self.max_seq.saturating_sub(
                reqs.get(i).map_or(1, |r| r.max_new_tokens).min(self.max_seq / 2),
            );
            let (t, l) = tokenize(prompt, self.max_seq);
            let l = (l as usize).min(budget.max(1)) as i32;
            tokens.extend_from_slice(&t);
            lengths.push(l);
        }

        let t0 = Instant::now();
        // Hot path (§Perf): keep the KV cache as an xla::Literal across
        // steps — the Tensor round-trip copied the (multi-MB) cache three
        // times per decoded token.
        let toks_lit = self.engine.input_literal(&prefill, 0, &Tensor::I32(tokens))?;
        let len_lit = self.engine.input_literal(&prefill, 1, &Tensor::I32(lengths.clone()))?;
        let mut out = self.engine.execute_literals(&prefill, &[toks_lit, len_lit])?;
        let prefill_secs = t0.elapsed().as_secs_f64();
        let mut kv = out.pop().context("missing kv output")?;
        let mut logits: Vec<f32> = out.pop().context("missing logits")?.to_vec()?;
        debug_assert_eq!(kv.size_bytes(), self.kv_elems_per_b * b * 4);

        let mut outputs: Vec<Vec<u8>> = vec![Vec::new(); reqs.len()];
        let mut done: Vec<bool> = (0..reqs.len()).map(|_| false).collect();
        let mut pos: Vec<i32> = lengths.clone();
        let max_new = reqs.iter().map(|r| r.max_new_tokens).max().unwrap_or(0);
        let mut steps = 0usize;
        let t1 = Instant::now();
        for step in 0..max_new {
            // Sample next token per live slot.
            let mut next: Vec<i32> = Vec::with_capacity(b);
            for slot in 0..b {
                let row = &logits[slot * self.vocab..(slot + 1) * self.vocab];
                let tok = if slot >= reqs.len() || done[slot] {
                    EOS
                } else {
                    sample(row, reqs[slot].temperature, step)
                };
                if slot < reqs.len() && !done[slot] {
                    if tok == EOS || outputs[slot].len() + 1 >= reqs[slot].max_new_tokens {
                        done[slot] = true;
                    }
                    if tok != EOS {
                        outputs[slot].push(tok as u8);
                        on_token(slot, tok as u8);
                    }
                }
                next.push(tok);
            }
            if done.iter().all(|&d| d) {
                break;
            }
            // Positions: the sampled token is written at current pos.
            let write_pos: Vec<i32> = pos
                .iter()
                .map(|&p| p.min(self.max_seq as i32 - 1))
                .collect();
            let next_lit = self.engine.input_literal(&decode, 1, &Tensor::I32(next))?;
            let pos_lit = self.engine.input_literal(&decode, 2, &Tensor::I32(write_pos))?;
            let mut out = self.engine.execute_literals(&decode, &[kv, next_lit, pos_lit])?;
            kv = out.pop().context("missing kv output")?;
            logits = out.pop().context("missing logits")?.to_vec()?;
            for p in pos.iter_mut() {
                *p = (*p + 1).min(self.max_seq as i32 - 1);
            }
            steps += 1;
        }
        let decode_secs = t1.elapsed().as_secs_f64();

        let results = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| GenResult {
                output: outputs[i].clone(),
                prompt_tokens: r.prompt.len().min(self.max_seq),
                generated_tokens: outputs[i].len(),
            })
            .collect();
        Ok((results, BatchTiming { prefill_secs, decode_secs, decode_steps: steps, batch_size: b }))
    }

    /// Begin an empty in-flight batch at the largest compiled bucket.
    /// See [`InflightBatch`].
    pub fn begin_inflight(&self) -> InflightBatch {
        let bucket = self.max_batch();
        InflightBatch {
            bucket,
            kv: vec![0.0; self.kv_planes * bucket * self.kv_slab],
            logits: vec![0.0; bucket * self.vocab],
            slots: (0..bucket).map(|_| None).collect(),
            poisoned: None,
        }
    }

    /// Prefill-on-join: admit one request into a free slot of an
    /// in-flight batch. Runs a small-bucket prefill for just this request
    /// and splices its KV rows into the batch cache, so co-resident
    /// requests keep decoding undisturbed. Returns the slot index.
    pub fn inflight_admit(&self, b: &mut InflightBatch, req: &GenRequest) -> Result<usize> {
        if let Some(msg) = &b.poisoned {
            bail!("in-flight batch poisoned by an earlier decode failure: {msg}");
        }
        let slot = b
            .slots
            .iter()
            .position(|s| s.is_none())
            .context("no free slot in the in-flight batch")?;
        // Same prompt budget as the static path: leave decode room.
        let budget = self
            .max_seq
            .saturating_sub(req.max_new_tokens.min(self.max_seq / 2))
            .max(1);
        let bb = self.bucket_for(1)?;
        let prefill = format!("generator_prefill_b{bb}");
        let mut tokens = Vec::with_capacity(bb * self.max_seq);
        let mut lengths = Vec::with_capacity(bb);
        for i in 0..bb {
            let prompt: &[u8] = if i == 0 { &req.prompt } else { b"." };
            let (t, l) = tokenize(prompt, self.max_seq);
            tokens.extend_from_slice(&t);
            lengths.push(if i == 0 { (l as usize).min(budget) as i32 } else { l });
        }
        let t0 = Instant::now();
        let out = self
            .engine
            .execute(&prefill, &[Tensor::I32(tokens), Tensor::I32(lengths.clone())])?;
        let prefill_secs = t0.elapsed().as_secs_f64();
        let kv1 = out[1].as_f32()?;
        let logits1 = out[0].as_f32()?;
        // Splice row 0 of the single-request KV [L,2,bb,H,S,Dh] into this
        // slot's slabs of the batch KV [L,2,bucket,H,S,Dh].
        let slab = self.kv_slab;
        for p in 0..self.kv_planes {
            let src = &kv1[p * bb * slab..][..slab];
            b.kv[(p * b.bucket + slot) * slab..][..slab].copy_from_slice(src);
        }
        b.logits[slot * self.vocab..][..self.vocab]
            .copy_from_slice(&logits1[..self.vocab]);
        b.slots[slot] = Some(InflightSlot {
            pos: lengths[0],
            steps: 0,
            out: Vec::new(),
            max_new: req.max_new_tokens,
            temperature: req.temperature,
            prompt_tokens: req.prompt.len().min(self.max_seq),
            service_secs: prefill_secs,
        });
        Ok(slot)
    }

    /// One decode step over the in-flight batch: sample each live slot's
    /// next token from the current logits, retire slots that emit EOS or
    /// hit their token cap (their slot frees *this* step — the continuous
    /// batching property), then execute one fixed-bucket decode for the
    /// survivors. `on_token` streams (slot, byte) as tokens are accepted.
    /// Each step's wall time is attributed evenly across the slots that
    /// decoded in it, so retired requests carry per-slot decode-step
    /// service instead of a uniform batch split.
    pub fn inflight_step(
        &self,
        b: &mut InflightBatch,
        on_token: &mut dyn FnMut(usize, u8),
    ) -> Result<Vec<InflightDone>> {
        if let Some(msg) = &b.poisoned {
            bail!("in-flight batch poisoned by an earlier decode failure: {msg}");
        }
        let mut retired = Vec::new();
        let mut next: Vec<i32> = vec![EOS; b.bucket];
        for slot_i in 0..b.bucket {
            let Some(s) = b.slots[slot_i].as_mut() else { continue };
            let done = if s.out.len() >= s.max_new {
                true
            } else {
                let row = &b.logits[slot_i * self.vocab..][..self.vocab];
                let tok = sample(row, s.temperature, s.steps);
                if tok != EOS {
                    s.out.push(tok as u8);
                    on_token(slot_i, tok as u8);
                    next[slot_i] = tok;
                }
                tok == EOS || s.out.len() >= s.max_new
            };
            if done {
                let s = b.slots[slot_i].take().unwrap();
                retired.push(InflightDone {
                    slot: slot_i,
                    result: GenResult {
                        generated_tokens: s.out.len(),
                        output: s.out,
                        prompt_tokens: s.prompt_tokens,
                    },
                    service_secs: s.service_secs,
                });
                next[slot_i] = EOS;
            }
        }
        let live: Vec<usize> =
            (0..b.bucket).filter(|&i| b.slots[i].is_some()).collect();
        if live.is_empty() {
            return Ok(retired);
        }
        let decode = format!("generator_decode_b{}", b.bucket);
        let write_pos: Vec<i32> = (0..b.bucket)
            .map(|i| {
                b.slots[i]
                    .as_ref()
                    .map_or(0, |s| s.pos.min(self.max_seq as i32 - 1))
            })
            .collect();
        let t0 = Instant::now();
        // A decode failure must not discard the requests that already
        // retired above (their outputs are complete): poison the batch
        // and still return them — the *next* step/admit errors, at which
        // point the caller drains the survivors and discards the batch.
        let kv_host = std::mem::take(&mut b.kv);
        let exec = (|| -> Result<(Vec<f32>, Vec<f32>)> {
            let kv_lit = self.engine.input_literal(&decode, 0, &Tensor::F32(kv_host))?;
            let next_lit = self.engine.input_literal(&decode, 1, &Tensor::I32(next))?;
            let pos_lit = self.engine.input_literal(&decode, 2, &Tensor::I32(write_pos))?;
            let mut out = self.engine.execute_literals(&decode, &[kv_lit, next_lit, pos_lit])?;
            let kv = out.pop().context("missing kv output")?.to_vec::<f32>()?;
            let logits = out.pop().context("missing logits")?.to_vec::<f32>()?;
            Ok((kv, logits))
        })();
        match exec {
            Ok((kv, logits)) => {
                b.kv = kv;
                b.logits = logits;
                let step_secs = t0.elapsed().as_secs_f64();
                let share = step_secs / live.len() as f64;
                for i in live {
                    let s = b.slots[i].as_mut().unwrap();
                    s.pos = (s.pos + 1).min(self.max_seq as i32 - 1);
                    s.steps += 1;
                    s.service_secs += share;
                }
            }
            Err(e) => {
                b.poisoned = Some(format!("{e:#}"));
            }
        }
        Ok(retired)
    }

    /// Single-token verdict (grader / critic): prefill and reduce the
    /// next-token distribution to a boolean. With the synthetic (randomly
    /// initialized) LM the absolute 'Y'/'N' logit margin is dominated by
    /// output-projection bias, so the verdict is derived from the argmax
    /// token's parity — deterministic per input, varies across inputs,
    /// which is what downstream control flow needs.
    pub fn verdict(&self, text: &[u8]) -> Result<bool> {
        let b = self.bucket_for(1)?;
        let prefill = format!("generator_prefill_b{b}");
        let mut tokens = Vec::with_capacity(b * self.max_seq);
        let mut lengths = Vec::with_capacity(b);
        for i in 0..b {
            let prompt: &[u8] = if i == 0 { text } else { b"." };
            let (t, l) = tokenize(prompt, self.max_seq);
            tokens.extend_from_slice(&t);
            lengths.push(l);
        }
        let out = self
            .engine
            .execute(&prefill, &[Tensor::I32(tokens), Tensor::I32(lengths)])?;
        let logits = out[0].as_f32()?;
        Ok(argmax(&logits[..self.vocab]) % 2 == 0)
    }
}

/// Greedy argmax or temperature sampling over a logit row.
fn sample(row: &[f32], temperature: Option<(f64, u64)>, step: usize) -> i32 {
    match temperature {
        None => argmax(row) as i32,
        Some((temp, seed)) => {
            let mut rng = crate::util::rng::Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37));
            let inv = 1.0 / temp.max(1e-3);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f64> =
                row.iter().map(|&l| (((l - m) as f64) * inv).exp()).collect();
            rng.weighted(&weights) as i32
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    fn generator() -> Option<Generator> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Generator::new(&default_artifacts_dir()).unwrap())
    }

    #[test]
    fn tokenize_pads_and_counts() {
        let (t, l) = tokenize(b"hi", 8);
        assert_eq!(l, 2);
        assert_eq!(t, vec![104, 105, 0, 0, 0, 0, 0, 0]);
        let (t, l) = tokenize(b"", 4);
        assert_eq!(l, 1);
        assert_eq!(t[0], 1);
    }

    #[test]
    fn tokenize_truncates() {
        let long = vec![65u8; 300];
        let (t, l) = tokenize(&long, 128);
        assert_eq!(t.len(), 128);
        assert_eq!(l, 128);
    }

    #[test]
    fn bucket_selection() {
        let Some(g) = generator() else { return };
        assert_eq!(g.bucket_for(1).unwrap(), 1);
        assert_eq!(g.bucket_for(3).unwrap(), 4);
        assert_eq!(g.bucket_for(8).unwrap(), 8);
        assert!(g.bucket_for(9).is_err());
    }

    #[test]
    fn generates_deterministic_greedy_output() {
        let Some(g) = generator() else { return };
        let req = GenRequest::greedy(b"What is the capital of France?", 8);
        let (r1, t1) = g.generate_batch(std::slice::from_ref(&req), |_, _| {}).unwrap();
        let (r2, _) = g.generate_batch(&[req], |_, _| {}).unwrap();
        assert_eq!(r1[0].output, r2[0].output, "greedy must be deterministic");
        assert!(r1[0].generated_tokens <= 8);
        assert!(t1.prefill_secs > 0.0);
        assert_eq!(t1.batch_size, 1);
    }

    #[test]
    fn batch_matches_single_request() {
        // Batching must not change a request's greedy output (prefill pads
        // other slots; attention is masked per-row).
        let Some(g) = generator() else { return };
        let a = GenRequest::greedy(b"hello world", 6);
        let bq = GenRequest::greedy(b"completely different prompt!", 6);
        let (solo, _) = g.generate_batch(std::slice::from_ref(&a), |_, _| {}).unwrap();
        let (duo, timing) = g.generate_batch(&[a, bq], |_, _| {}).unwrap();
        assert_eq!(solo[0].output, duo[0].output);
        assert_eq!(timing.batch_size, 2);
    }

    #[test]
    fn streaming_callback_sees_every_token() {
        let Some(g) = generator() else { return };
        let req = GenRequest::greedy(b"stream me", 6);
        let mut streamed = Vec::new();
        let (res, _) = g
            .generate_batch(&[req], |slot, byte| {
                assert_eq!(slot, 0);
                streamed.push(byte);
            })
            .unwrap();
        assert_eq!(streamed, res[0].output);
    }

    #[test]
    fn inflight_matches_static_greedy_output() {
        // Per-row attention masking means a request decodes the same
        // tokens whether it runs solo, statically batched, or spliced
        // into a continuous batch.
        let Some(g) = generator() else { return };
        let req = GenRequest::greedy(b"What is the capital of France?", 8);
        let (solo, _) = g.generate_batch(std::slice::from_ref(&req), |_, _| {}).unwrap();
        let mut b = g.begin_inflight();
        let slot = g.inflight_admit(&mut b, &req).unwrap();
        assert_eq!(b.occupancy(), 1);
        let mut done = Vec::new();
        let mut streamed = Vec::new();
        for _ in 0..64 {
            let mut retired = g
                .inflight_step(&mut b, &mut |s, byte| {
                    assert_eq!(s, slot);
                    streamed.push(byte);
                })
                .unwrap();
            done.append(&mut retired);
            if b.occupancy() == 0 {
                break;
            }
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].slot, slot);
        assert_eq!(done[0].result.output, solo[0].output);
        assert_eq!(streamed, solo[0].output, "tokens stream per step");
        assert!(done[0].service_secs > 0.0);
    }

    #[test]
    fn short_request_retires_while_long_keeps_decoding() {
        // The continuous-batching property: a slot frees the step its
        // request finishes, and its service attribution stops there — it
        // does not wait out a co-batched longer request.
        let Some(g) = generator() else { return };
        let mut b = g.begin_inflight();
        let long = GenRequest::greedy(b"a long elaborate question needing detail", 16);
        let short = GenRequest::greedy(b"hi", 2);
        let ls = g.inflight_admit(&mut b, &long).unwrap();
        let ss = g.inflight_admit(&mut b, &short).unwrap();
        assert_ne!(ls, ss);
        assert_eq!(b.occupancy(), 2);
        let mut order = Vec::new();
        let mut short_done = None;
        let mut long_done = None;
        for _ in 0..64 {
            for d in g.inflight_step(&mut b, &mut |_, _| {}).unwrap() {
                order.push(d.slot);
                if d.slot == ss {
                    short_done = Some(d);
                } else {
                    long_done = Some(d);
                }
            }
            if b.occupancy() == 0 {
                break;
            }
        }
        let (s, l) = (short_done.expect("short finished"), long_done.expect("long finished"));
        assert!(s.result.generated_tokens <= 2);
        // With a synthetic LM the long request *may* emit EOS early; the
        // continuous-batching invariants are asserted whenever it really
        // decoded longer (the common case with a 16-token cap).
        if l.result.generated_tokens > s.result.generated_tokens {
            assert_eq!(order.first(), Some(&ss), "short retires first, freeing its slot");
            assert!(
                s.service_secs < l.service_secs,
                "per-slot decode-step attribution: short {} !< long {}",
                s.service_secs,
                l.service_secs
            );
        }
    }

    #[test]
    fn inflight_admission_after_retirement_reuses_slots() {
        // Prefill-on-join into a freed slot must not disturb a resident
        // request: run A+B, retire B, admit C into the freed slot, and A
        // must still produce its solo greedy output.
        let Some(g) = generator() else { return };
        let a = GenRequest::greedy(b"first resident request", 12);
        let (a_solo, _) = g.generate_batch(std::slice::from_ref(&a), |_, _| {}).unwrap();
        let mut batch = g.begin_inflight();
        let a_slot = g.inflight_admit(&mut batch, &a).unwrap();
        let b_req = GenRequest::greedy(b"quick", 1);
        g.inflight_admit(&mut batch, &b_req).unwrap();
        let mut a_out = None;
        let mut admitted_c = false;
        for _ in 0..64 {
            for d in g.inflight_step(&mut batch, &mut |_, _| {}).unwrap() {
                if d.slot == a_slot {
                    a_out = Some(d.result.output);
                } else if !admitted_c {
                    // B retired: splice C into the freed batch mid-flight.
                    let c = GenRequest::greedy(b"late joiner", 4);
                    g.inflight_admit(&mut batch, &c).unwrap();
                    admitted_c = true;
                }
            }
            if a_out.is_some() {
                break;
            }
        }
        assert!(admitted_c, "B must retire before A's 12-token budget");
        assert_eq!(a_out.expect("A finished"), a_solo[0].output);
    }

    #[test]
    fn verdict_is_deterministic_and_input_sensitive() {
        let Some(g) = generator() else { return };
        let a = g.verdict(b"Does retrieved doc have relevant info? doc: Paris is in France").unwrap();
        let a2 = g.verdict(b"Does retrieved doc have relevant info? doc: Paris is in France").unwrap();
        assert_eq!(a, a2);
        // Across many inputs both verdicts occur (not a constant function).
        let mut seen = std::collections::HashSet::new();
        for i in 0..8 {
            let text = format!("judge this doc number {i} with content xyz{i}");
            seen.insert(g.verdict(text.as_bytes()).unwrap());
        }
        assert_eq!(seen.len(), 2, "verdict should vary with input");
    }
}
