//! Overload-control observability: admission / shedding / degradation
//! counters shared by the live controller and the DES, plus the snapshot
//! type embedded in [`crate::metrics::RunReport`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters updated on the admission/dispatch path (relaxed
/// atomics: statistics, not synchronization — live workers and the
/// controller thread update them concurrently).
#[derive(Debug, Default)]
pub struct SchedCounters {
    /// Requests admitted into the pipeline.
    pub admitted: AtomicU64,
    /// Requests shed because predicted slack was already negative.
    pub shed_slack: AtomicU64,
    /// Requests shed by queue-depth backpressure.
    pub shed_backpressure: AtomicU64,
    /// Component visits served at reduced fidelity (top-k shrunk, hop
    /// skipped, or loop iteration clamped).
    pub degraded: AtomicU64,
}

impl SchedCounters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn on_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_shed_slack(&self) {
        self.shed_slack.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_shed_backpressure(&self) {
        self.shed_backpressure.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` degraded visits at once (batched stages).
    #[inline]
    pub fn on_degraded_n(&self, n: u64) {
        self.degraded.fetch_add(n, Ordering::Relaxed);
    }

    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> SchedSnapshot {
        SchedSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            shed_slack: self.shed_slack.load(Ordering::Relaxed),
            shed_backpressure: self.shed_backpressure.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
        }
    }
}

/// Frozen counter values; the overload-control row a run prints.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedSnapshot {
    pub admitted: u64,
    pub shed_slack: u64,
    pub shed_backpressure: u64,
    pub degraded: u64,
}

impl SchedSnapshot {
    /// Total requests shed at admission.
    pub fn shed(&self) -> u64 {
        self.shed_slack + self.shed_backpressure
    }

    /// Total offered load that reached the admission gate.
    pub fn offered(&self) -> u64 {
        self.admitted + self.shed()
    }

    /// Fraction of offered requests shed; 0 when nothing was offered.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            0.0
        } else {
            self.shed() as f64 / offered as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = SchedCounters::new();
        c.on_admitted();
        c.on_admitted();
        c.on_admitted();
        c.on_shed_slack();
        c.on_shed_backpressure();
        c.on_degraded();
        c.on_degraded_n(2);
        let s = c.snapshot();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.shed(), 2);
        assert_eq!(s.offered(), 5);
        assert!((s.shed_rate() - 0.4).abs() < 1e-12);
        assert_eq!(s.degraded, 3);
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = SchedSnapshot::default();
        assert_eq!(s.shed_rate(), 0.0);
        assert_eq!(s.offered(), 0);
    }
}
