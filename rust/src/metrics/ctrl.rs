//! Controller-loop self-observability: busy/idle/dispatch accounting for
//! the live serving hot loop.
//!
//! The controller is a single thread multiplexing submissions,
//! completions, and control-plane ticks; at the million-user scale the
//! ROADMAP targets, *its* per-hop overhead is the serving ceiling no
//! worker pool can raise. These counters make that overhead a first-class
//! metric: `benches/perf_live.rs` derives its per-hop dispatch number
//! from them, and any normal run can do the same via
//! `RunReport::ctrl`.

/// Aggregate controller-loop counters (attached to `RunReport` by live
/// runs; absent for DES runs, which have no controller thread).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CtrlStats {
    /// WorkItems handed to workers (one per hop, including fork fan-out).
    pub dispatches: u64,
    /// Seconds spent inside the dispatch path (instance snapshot +
    /// routing + channel send), summed across dispatches.
    pub dispatch_secs: f64,
    /// Completion messages processed.
    pub completions: u64,
    /// Seconds the controller thread spent processing messages.
    pub busy_secs: f64,
    /// Seconds the controller thread spent blocked on its inbox.
    pub idle_secs: f64,
}

impl CtrlStats {
    /// Mean dispatch-path overhead per hop, in nanoseconds.
    pub fn dispatch_ns_per_hop(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.dispatch_secs / self.dispatches as f64 * 1e9
        }
    }

    /// Fraction of loop wall time spent processing (vs blocked waiting).
    pub fn busy_frac(&self) -> f64 {
        let total = self.busy_secs + self.idle_secs;
        if total <= 0.0 {
            0.0
        } else {
            self.busy_secs / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_rates_handle_zero_counts() {
        let s = CtrlStats::default();
        assert_eq!(s.dispatch_ns_per_hop(), 0.0);
        assert_eq!(s.busy_frac(), 0.0);
    }

    #[test]
    fn derived_rates_compute() {
        let s = CtrlStats {
            dispatches: 1000,
            dispatch_secs: 0.001,
            completions: 900,
            busy_secs: 1.0,
            idle_secs: 3.0,
        };
        assert!((s.dispatch_ns_per_hop() - 1000.0).abs() < 1e-6);
        assert!((s.busy_frac() - 0.25).abs() < 1e-12);
    }
}
