//! Metrics: per-request and per-component recording, SLO accounting, and
//! the report types the bench harnesses print.

pub mod cache;
pub mod ctrl;
pub mod recorder;
pub mod sched;

pub use cache::{CacheCounters, CacheSnapshot};
pub use ctrl::CtrlStats;
pub use recorder::{ComponentStats, DisaggStats, GenStats, Recorder, RunReport};
pub use sched::{SchedCounters, SchedSnapshot};
