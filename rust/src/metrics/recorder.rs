//! Run-level metrics recorder shared by the DES, the live coordinator and
//! the baselines, so every system is measured identically.

use std::collections::HashMap;

use crate::metrics::cache::CacheSnapshot;
use crate::metrics::ctrl::CtrlStats;
use crate::metrics::sched::SchedSnapshot;
use crate::stats::percentile::percentile;

/// Aggregated per-component execution statistics.
#[derive(Clone, Debug, Default)]
pub struct ComponentStats {
    /// Total busy time across instances (seconds).
    pub busy_time: f64,
    /// Number of executions.
    pub executions: u64,
    /// Total time requests spent queued at this component.
    pub queue_time: f64,
    /// Total time completed fork branches stalled at this component's
    /// join barrier waiting for their siblings (join nodes only; 0
    /// elsewhere). Surfaces fork stall time that would otherwise fold
    /// invisibly into end-to-end latency.
    pub join_wait: f64,
    /// Barrier releases recorded at this component (join nodes only).
    pub joins: u64,
}

impl ComponentStats {
    pub fn mean_service(&self) -> f64 {
        if self.executions == 0 {
            0.0
        } else {
            self.busy_time / self.executions as f64
        }
    }

    pub fn mean_queue(&self) -> f64 {
        if self.executions == 0 {
            0.0
        } else {
            self.queue_time / self.executions as f64
        }
    }

    /// Mean sibling stall per barrier release (0 for non-join nodes).
    pub fn mean_join_wait(&self) -> f64 {
        if self.joins == 0 {
            0.0
        } else {
            self.join_wait / self.joins as f64
        }
    }
}

/// Generation-path latency statistics (continuous-batching metrics):
/// time-to-first-token and per-output-token pace, the two axes static
/// run-to-completion batching degrades. `None` in [`RunReport::gen`]
/// when no samples were recorded (legacy aggregate modeling, or a run
/// with no generator stage).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GenStats {
    /// Samples behind each series.
    pub samples: u64,
    /// Time from request arrival to its first generated token (s).
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// Per-output-token latency after the first token (s/token).
    pub tok_p50: f64,
    pub tok_p99: f64,
}

/// Prefill/decode disaggregation statistics — present in
/// [`RunReport::disagg`] only when the run served the generator under
/// `GenPlacement::Disaggregated` (collocated runs, including every
/// golden trace, must not grow this section).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DisaggStats {
    /// KV handoffs completed (one per prefill that reached a decode pool).
    pub handoffs: u64,
    /// Total seconds spent in KV transfer across all handoffs.
    pub transfer_total: f64,
    /// Prefill-pool instances provisioned at run start.
    pub prefill_instances: usize,
    /// Decode-pool instances provisioned at run start.
    pub decode_instances: usize,
    /// KV prefix-cache counters (zeroed snapshot when the prefix cache
    /// is off).
    pub kv_prefix: CacheSnapshot,
}

impl DisaggStats {
    /// Mean per-handoff transfer cost (0 when nothing handed off).
    pub fn mean_transfer(&self) -> f64 {
        if self.handoffs == 0 {
            0.0
        } else {
            self.transfer_total / self.handoffs as f64
        }
    }
}

/// Collects per-request completions and per-component stats during a run.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    latencies: Vec<f64>,
    violations: u64,
    completed: u64,
    /// Requests shed by admission control (never entered the pipeline).
    shed: u64,
    first_arrival: Option<f64>,
    last_completion: f64,
    pub components: HashMap<String, ComponentStats>,
    /// Time-to-first-token samples (one per request reaching a stepped
    /// generator stage).
    ttft: Vec<f64>,
    /// Per-output-token latency samples (one per generator visit).
    tok_lat: Vec<f64>,
    /// Cache counters captured at the end of the run (None = no cache).
    cache: Option<CacheSnapshot>,
    /// Live KV prefix-cache counters (None = no prefix cache deployed).
    kv_prefix: Option<CacheSnapshot>,
    /// Overload-control counters (None = stock control plane).
    sched: Option<SchedSnapshot>,
    /// Disaggregation counters (None = collocated generator).
    disagg: Option<DisaggStats>,
    /// Controller-loop counters (None = no live controller, e.g. DES).
    ctrl: Option<CtrlStats>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_arrival(&mut self, t: f64) {
        if self.first_arrival.is_none() {
            self.first_arrival = Some(t);
        }
    }

    /// Record a completed request.
    pub fn on_completion(&mut self, arrival: f64, completion: f64, deadline: Option<f64>) {
        let latency = completion - arrival;
        debug_assert!(latency >= 0.0);
        self.latencies.push(latency);
        self.completed += 1;
        self.last_completion = self.last_completion.max(completion);
        if let Some(d) = deadline {
            if completion > d {
                self.violations += 1;
            }
        }
    }

    /// Per-component stats entry by name, allocating the `String` key
    /// only on the component's first visit. `on_execution` fires once
    /// per simulated stage execution (tens of millions of times in a
    /// perf-bench run), so the steady-state path must not allocate.
    fn comp_mut(&mut self, component: &str) -> &mut ComponentStats {
        if !self.components.contains_key(component) {
            self.components.insert(component.to_string(), ComponentStats::default());
        }
        self.components.get_mut(component).expect("just inserted")
    }

    /// Record one component execution.
    pub fn on_execution(&mut self, component: &str, service: f64, queued: f64) {
        let e = self.comp_mut(component);
        e.busy_time += service;
        e.executions += 1;
        e.queue_time += queued;
    }

    /// Record one barrier release at a join component: `stall` is the
    /// total time already-arrived branches spent waiting for the arrival
    /// that released the barrier.
    pub fn on_join_wait(&mut self, component: &str, stall: f64) {
        debug_assert!(stall >= 0.0);
        let e = self.comp_mut(component);
        e.join_wait += stall;
        e.joins += 1;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Record a request shed at admission (counted separately from
    /// completions: shed requests never produce a latency sample and
    /// never count against the SLO violation rate).
    pub fn on_shed(&mut self) {
        self.shed += 1;
    }

    /// Record a request's time-to-first-token (arrival → first generated
    /// token). Call at most once per request.
    pub fn on_first_token(&mut self, ttft: f64) {
        debug_assert!(ttft >= 0.0);
        self.ttft.push(ttft);
    }

    /// Record one generator visit's per-output-token latency.
    pub fn on_token_latency(&mut self, secs_per_token: f64) {
        debug_assert!(secs_per_token >= 0.0);
        self.tok_lat.push(secs_per_token);
    }

    /// Attach the run's cache counter snapshot (shows up in the report).
    pub fn set_cache(&mut self, snapshot: CacheSnapshot) {
        self.cache = Some(snapshot);
    }

    /// Attach the live KV prefix-cache counter snapshot (`cache::kv_prefix`
    /// deployments only; the DES's modeled twin reports through
    /// [`RunReport::disagg`] instead).
    pub fn set_kv_prefix(&mut self, snapshot: CacheSnapshot) {
        self.kv_prefix = Some(snapshot);
    }

    /// Attach the run's overload-control counter snapshot.
    pub fn set_sched(&mut self, snapshot: SchedSnapshot) {
        self.sched = Some(snapshot);
    }

    /// Attach the run's disaggregation counters (disaggregated runs only;
    /// collocated runs never call this, keeping the report section absent
    /// by default).
    pub fn set_disagg(&mut self, stats: DisaggStats) {
        self.disagg = Some(stats);
    }

    /// Attach the controller loop's busy/idle/dispatch counters (live
    /// runs only; DES runs have no controller thread and leave the
    /// report section absent).
    pub fn set_ctrl(&mut self, stats: CtrlStats) {
        self.ctrl = Some(stats);
    }

    /// Finalize into a report.
    pub fn report(&self) -> RunReport {
        // `total_cmp` sorts: a NaN latency sample (a model bug) lands at
        // the end of the order instead of panicking mid-report — the DES
        // rejects non-finite event times at the source, and the report
        // stays diagnosable either way.
        let mut lats = self.latencies.clone();
        lats.sort_by(f64::total_cmp);
        let horizon = self.last_completion - self.first_arrival.unwrap_or(0.0);
        let gen = if self.ttft.is_empty() && self.tok_lat.is_empty() {
            None
        } else {
            let mut ttft = self.ttft.clone();
            ttft.sort_by(f64::total_cmp);
            let mut tok = self.tok_lat.clone();
            tok.sort_by(f64::total_cmp);
            let pct = |v: &[f64], p: f64| if v.is_empty() { 0.0 } else { percentile(v, p) };
            Some(GenStats {
                samples: (ttft.len().max(tok.len())) as u64,
                ttft_p50: pct(&ttft, 50.0),
                ttft_p99: pct(&ttft, 99.0),
                tok_p50: pct(&tok, 50.0),
                tok_p99: pct(&tok, 99.0),
            })
        };
        RunReport {
            completed: self.completed,
            throughput: if horizon > 0.0 { self.completed as f64 / horizon } else { 0.0 },
            mean_latency: if lats.is_empty() { 0.0 } else { lats.iter().sum::<f64>() / lats.len() as f64 },
            p50: if lats.is_empty() { 0.0 } else { percentile(&lats, 50.0) },
            p95: if lats.is_empty() { 0.0 } else { percentile(&lats, 95.0) },
            p99: if lats.is_empty() { 0.0 } else { percentile(&lats, 99.0) },
            slo_violation_rate: if self.completed == 0 {
                0.0
            } else {
                self.violations as f64 / self.completed as f64
            },
            components: self.components.clone(),
            gen,
            cache: self.cache,
            kv_prefix: self.kv_prefix,
            shed: self.shed,
            sched: self.sched,
            disagg: self.disagg,
            ctrl: self.ctrl,
        }
    }
}

/// Final metrics of one serving run — the row format of Figs. 9/11.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub completed: u64,
    /// Completions per second over the active horizon.
    pub throughput: f64,
    pub mean_latency: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Fraction of completed requests that missed their deadline.
    pub slo_violation_rate: f64,
    pub components: HashMap<String, ComponentStats>,
    /// TTFT / per-token latency, when the run modeled the generator at
    /// decode-step granularity (`GenBatching::{Static, Continuous}`);
    /// `None` under the legacy aggregate model.
    pub gen: Option<GenStats>,
    /// Query-cache counters, if the run served through a cache.
    pub cache: Option<CacheSnapshot>,
    /// Live KV prefix-cache counters, if the deployment ran one in front
    /// of generator prefill (`cache::kv_prefix`); the DES's *modeled*
    /// prefix cache reports under [`RunReport::disagg`] instead.
    pub kv_prefix: Option<CacheSnapshot>,
    /// Requests shed at admission (0 with the stock control plane).
    pub shed: u64,
    /// Overload-control counters, if any sched policy was enabled.
    pub sched: Option<SchedSnapshot>,
    /// Prefill/decode disaggregation counters, if the run served the
    /// generator split (`None` for collocated runs — golden traces pin
    /// the absence).
    pub disagg: Option<DisaggStats>,
    /// Controller-loop busy/idle/dispatch counters (live runs only; the
    /// per-hop dispatch overhead `benches/perf_live.rs` headlines is
    /// derivable from any normal run through this).
    pub ctrl: Option<CtrlStats>,
}

impl RunReport {
    /// Goodput: SLO-meeting completions per second over the active
    /// horizon — the figure of merit under overload (raw throughput
    /// rewards serving requests that already blew their deadline).
    pub fn goodput(&self) -> f64 {
        self.throughput * (1.0 - self.slo_violation_rate)
    }

    /// Per-node latency/visit breakdown (queue vs service vs join-wait)
    /// rendered with `util::table` — the bench harnesses print this so
    /// fork stall time is visible instead of folded into end-to-end
    /// latency. Rows are name-sorted for deterministic output.
    pub fn breakdown_table(&self, title: &str) -> String {
        let mut names: Vec<&String> = self.components.keys().collect();
        names.sort();
        let mut t = crate::util::table::Table::new(
            title,
            &["component", "visits", "queue ms", "service ms", "join-wait ms", "busy s"],
        );
        for name in names {
            let c = &self.components[name];
            t.row(&[
                name.clone(),
                c.executions.to_string(),
                crate::util::table::f(c.mean_queue() * 1e3, 2),
                crate::util::table::f(c.mean_service() * 1e3, 2),
                crate::util::table::f(c.mean_join_wait() * 1e3, 2),
                crate::util::table::f(c.busy_time, 2),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accounting() {
        let mut r = Recorder::new();
        r.on_arrival(0.0);
        r.on_completion(0.0, 1.0, Some(2.0)); // within SLO
        r.on_completion(1.0, 4.0, Some(2.0)); // violation
        r.on_completion(2.0, 3.0, None); // no deadline
        let rep = r.report();
        assert_eq!(rep.completed, 3);
        assert!((rep.slo_violation_rate - 1.0 / 3.0).abs() < 1e-12);
        assert!((rep.mean_latency - (1.0 + 3.0 + 1.0) / 3.0).abs() < 1e-12);
        // horizon = 4.0 - 0.0
        assert!((rep.throughput - 3.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn component_stats() {
        let mut r = Recorder::new();
        r.on_execution("grader", 0.2, 0.1);
        r.on_execution("grader", 0.4, 0.3);
        let rep = r.report();
        let g = &rep.components["grader"];
        assert_eq!(g.executions, 2);
        assert!((g.mean_service() - 0.3).abs() < 1e-12);
        assert!((g.mean_queue() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn join_wait_tracked_and_rendered() {
        let mut r = Recorder::new();
        r.on_execution("generator", 0.1, 0.0);
        r.on_join_wait("generator", 0.05);
        r.on_join_wait("generator", 0.07);
        let rep = r.report();
        let g = &rep.components["generator"];
        assert_eq!(g.joins, 2);
        assert!((g.mean_join_wait() - 0.06).abs() < 1e-12);
        // Non-join components stay at zero.
        r.on_execution("retriever", 0.1, 0.0);
        assert_eq!(r.report().components["retriever"].mean_join_wait(), 0.0);
        let table = rep.breakdown_table("breakdown");
        assert!(table.contains("join-wait ms"), "{table}");
        assert!(table.contains("generator"), "{table}");
        assert!(table.contains("60.00"), "mean join wait in ms: {table}");
    }

    #[test]
    fn percentiles_ordered() {
        let mut r = Recorder::new();
        r.on_arrival(0.0);
        for i in 0..100 {
            r.on_completion(0.0, (i + 1) as f64 * 0.01, None);
        }
        let rep = r.report();
        assert!(rep.p50 <= rep.p95 && rep.p95 <= rep.p99);
    }

    #[test]
    fn empty_recorder_safe() {
        let rep = Recorder::new().report();
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.throughput, 0.0);
        assert!(rep.cache.is_none());
        assert_eq!(rep.shed, 0);
        assert!(rep.sched.is_none());
        assert!(rep.gen.is_none(), "no decode-step samples → no gen section");
        assert!(rep.disagg.is_none(), "no handoffs → no disaggregation section");
        assert!(rep.ctrl.is_none(), "no live controller → no ctrl section");
    }

    #[test]
    fn ctrl_stats_travel_into_report() {
        let mut r = Recorder::new();
        r.on_arrival(0.0);
        r.on_completion(0.0, 1.0, None);
        let stats = CtrlStats {
            dispatches: 10,
            dispatch_secs: 0.00001,
            completions: 10,
            busy_secs: 0.5,
            idle_secs: 0.5,
        };
        r.set_ctrl(stats);
        let rep = r.report();
        assert_eq!(rep.ctrl, Some(stats));
        assert!((rep.ctrl.unwrap().dispatch_ns_per_hop() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn disagg_stats_travel_into_report() {
        let mut r = Recorder::new();
        let stats = DisaggStats {
            handoffs: 4,
            transfer_total: 0.02,
            prefill_instances: 2,
            decode_instances: 6,
            kv_prefix: CacheSnapshot { exact_hits: 3, misses: 1, ..Default::default() },
        };
        r.set_disagg(stats);
        let rep = r.report();
        assert_eq!(rep.disagg, Some(stats));
        assert!((rep.disagg.unwrap().mean_transfer() - 0.005).abs() < 1e-12);
        assert!((rep.disagg.unwrap().kv_prefix.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(DisaggStats::default().mean_transfer(), 0.0);
    }

    #[test]
    fn gen_stats_percentiles_from_samples() {
        let mut r = Recorder::new();
        for i in 0..100 {
            r.on_first_token(0.01 * (i + 1) as f64);
            r.on_token_latency(0.002 + 1e-5 * i as f64);
        }
        let g = r.report().gen.expect("gen section present");
        assert_eq!(g.samples, 100);
        assert!(g.ttft_p50 <= g.ttft_p99);
        assert!(g.tok_p50 <= g.tok_p99);
        assert!((0.4..0.7).contains(&g.ttft_p50), "ttft p50 {}", g.ttft_p50);
        assert!(g.tok_p99 < 0.01);
    }

    #[test]
    fn shed_and_sched_travel_into_report() {
        let mut r = Recorder::new();
        r.on_arrival(0.0);
        r.on_shed();
        r.on_shed();
        r.on_completion(0.0, 1.0, Some(0.5)); // one violating completion
        let snap = SchedSnapshot { admitted: 1, shed_slack: 2, ..Default::default() };
        r.set_sched(snap);
        let rep = r.report();
        assert_eq!(rep.shed, 2);
        assert_eq!(rep.completed, 1, "shed requests are not completions");
        assert_eq!(rep.slo_violation_rate, 1.0, "violations counted over completions only");
        assert_eq!(rep.sched, Some(snap));
        // goodput = throughput × SLO-meeting fraction.
        assert_eq!(rep.goodput(), 0.0);
    }

    #[test]
    fn cache_snapshot_travels_into_report() {
        let mut r = Recorder::new();
        let snap = CacheSnapshot { exact_hits: 5, misses: 5, ..Default::default() };
        r.set_cache(snap);
        let rep = r.report();
        assert_eq!(rep.cache, Some(snap));
        assert!((rep.cache.unwrap().hit_rate() - 0.5).abs() < 1e-12);
    }
}
