//! Cache observability: lock-free hit/miss/stale counters shared by the
//! live `cache::QueryCache` and the DES's modeled cache, plus the
//! snapshot type embedded in [`crate::metrics::RunReport`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters updated on the request hot path (relaxed atomics:
/// the counters are statistics, not synchronization).
#[derive(Debug, Default)]
pub struct CacheCounters {
    /// Exact-tier hits (normalized query text matched).
    pub exact_hits: AtomicU64,
    /// Semantic-tier hits (embedding within the similarity threshold).
    pub semantic_hits: AtomicU64,
    /// Lookups that fell through both tiers.
    pub misses: AtomicU64,
    /// Entries rejected (and dropped) because their TTL had expired.
    pub stale: AtomicU64,
    /// Entries evicted by capacity pressure.
    pub evictions: AtomicU64,
    /// Entries written.
    pub insertions: AtomicU64,
}

impl CacheCounters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn on_exact_hit(&self) {
        self.exact_hits.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_semantic_hit(&self) {
        self.semantic_hits.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_stale(&self) {
        self.stale.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn on_insertion(&self) {
        self.insertions.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            exact_hits: self.exact_hits.load(Ordering::Relaxed),
            semantic_hits: self.semantic_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stale: self.stale.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
        }
    }
}

/// Frozen counter values; the report row a run prints.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub exact_hits: u64,
    pub semantic_hits: u64,
    pub misses: u64,
    pub stale: u64,
    pub evictions: u64,
    pub insertions: u64,
}

impl CacheSnapshot {
    /// Total lookups that reached the cache.
    pub fn lookups(&self) -> u64 {
        self.exact_hits + self.semantic_hits + self.misses
    }

    /// Combined (exact + semantic) hit rate; 0 when no lookups happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.lookups();
        if total == 0 {
            0.0
        } else {
            (self.exact_hits + self.semantic_hits) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = CacheCounters::new();
        c.on_exact_hit();
        c.on_exact_hit();
        c.on_semantic_hit();
        c.on_miss();
        c.on_stale();
        c.on_insertion();
        let s = c.snapshot();
        assert_eq!(s.exact_hits, 2);
        assert_eq!(s.semantic_hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.stale, 1);
        assert_eq!(s.insertions, 1);
        assert_eq!(s.lookups(), 4);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_hit_rate_is_zero() {
        assert_eq!(CacheSnapshot::default().hit_rate(), 0.0);
        assert_eq!(CacheSnapshot::default().lookups(), 0);
    }
}
