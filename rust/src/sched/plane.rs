//! The unified control plane: one policy object both execution backends
//! drive instead of reimplementing.
//!
//! [`ControlPlane`] bundles every per-request policy — routing, predicted
//! slack, queue keys, admission, degradation — plus the periodic tick
//! (admission ladder → queue rekey → autoscale). It is **clock-agnostic**:
//! every method takes `now` in seconds from an arbitrary epoch, so the
//! DES drives it with virtual time and the live controller with
//! `util::clock::WallClock`. Neither backend holds policy logic anymore;
//! `sim::simrun::SimWorld` and `coordinator::controller` keep only the
//! execution mechanics (event wiring / worker channels) and delegate
//! every decision here.
//!
//! Division of labor for the tick: the plane decides *whether* to rekey
//! and *what* the new keys are ([`ControlPlane::slack_value`]); the
//! caller owns the queues and applies the rekey mechanically (queues are
//! execution state — the DES holds `PrioQueue`s, the live path holds
//! worker channels that cannot reorder).

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::autoscaler::Autoscaler;
use crate::coordinator::router::{InstanceState, Router, RoutingPolicy};
use crate::coordinator::telemetry::Telemetry;
use crate::metrics::SchedCounters;
use crate::profile::models::{degrade_service_factor, RequestFeatures};
use crate::profile::Profile;
use crate::spec::graph::{DegradeKnob, NodeId, PipelineGraph, ResourceKind};

use super::admission::{AdmissionController, AdmissionDecision};
use super::degrade::{DegradePolicy, OverloadLevel};
use super::queue::{QueueDiscipline, SlackPredictor};

/// All overload-control knobs in one place. **Everything defaults off**:
/// a default-configured plane admits every request, never degrades, and
/// never rekeys — byte-for-byte the pre-refactor behavior, which is what
/// keeps `golden_trace.rs` bit-identical.
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedConfig {
    pub admission: super::admission::AdmissionConfig,
    pub degrade: super::degrade::DegradeConfig,
    /// Re-key LeastSlack queues on the control tick (slack decays as
    /// time passes; without rekey, EDF order is frozen at enqueue time).
    pub rekey_on_tick: bool,
}

impl SchedConfig {
    /// Every overload defense on (admission + degradation + rekey) with
    /// default thresholds — the bench/test preset.
    pub fn overload_defense() -> Self {
        SchedConfig {
            admission: super::admission::AdmissionConfig {
                enabled: true,
                ..Default::default()
            },
            degrade: super::degrade::DegradeConfig { enabled: true, ..Default::default() },
            rekey_on_tick: true,
        }
    }

    /// Is any non-default policy active (i.e. should the run attach a
    /// sched section to its report)?
    pub fn enabled(&self) -> bool {
        self.admission.enabled || self.degrade.enabled || self.rekey_on_tick
    }
}

/// What one control tick decided.
#[derive(Clone, Debug)]
pub struct TickOutcome {
    /// Overload level published for this interval.
    pub level: OverloadLevel,
    /// Caller should rebuild its LeastSlack queues with fresh
    /// [`ControlPlane::slack_value`] keys.
    pub rekey: bool,
    /// Committed reallocation plan (deployable units per node), if the
    /// autoscaler's damping rule fired.
    pub plan: Option<HashMap<NodeId, usize>>,
}

/// The shared scheduling control plane.
pub struct ControlPlane {
    pub cfg: SchedConfig,
    pub router: Router,
    pub slack: SlackPredictor,
    pub telemetry: Telemetry,
    pub autoscaler: Autoscaler,
    pub admission: AdmissionController,
    pub degrade: DegradePolicy,
    pub discipline: QueueDiscipline,
    /// Shared atomics so live workers can report degraded visits.
    pub counters: Arc<SchedCounters>,
}

impl ControlPlane {
    /// Build a plane over a pipeline's deploy-time priors.
    /// `autoscale_interval` is in clock seconds (virtual or wall).
    pub fn new(
        graph: &PipelineGraph,
        prior_mean_service: &HashMap<NodeId, f64>,
        routing: RoutingPolicy,
        discipline: QueueDiscipline,
        cfg: SchedConfig,
        autoscale_interval: f64,
    ) -> ControlPlane {
        ControlPlane {
            // Pre-size the router's dense per-node tables so the hot
            // path never grows them mid-dispatch.
            router: Router::with_nodes(routing, graph.nodes.len()),
            slack: SlackPredictor::new(graph, prior_mean_service),
            telemetry: Telemetry::new(graph),
            autoscaler: Autoscaler::new(autoscale_interval),
            admission: AdmissionController::new(cfg.admission),
            degrade: DegradePolicy::new(cfg.degrade),
            discipline,
            counters: Arc::new(SchedCounters::new()),
            cfg,
        }
    }

    /// Swap in externally shared state (live path: workers hold the same
    /// degrade cell and counters the controller updates).
    pub fn share(
        mut self,
        cell: Arc<super::degrade::OverloadCell>,
        counters: Arc<SchedCounters>,
    ) -> ControlPlane {
        self.degrade = DegradePolicy::with_cell(self.cfg.degrade, cell);
        self.counters = counters;
        self
    }

    // ---- admission ---------------------------------------------------------

    pub fn admission_enabled(&self) -> bool {
        self.admission.cfg.enabled
    }

    /// Predicted slack at admission: deadline − now − predicted pipeline
    /// service − predicted queue wait at the entry component. The wait
    /// term is what makes admission bite under overload — by the time a
    /// backlog is worth shedding over, queueing dominates service.
    pub fn admission_slack(
        &self,
        entry: NodeId,
        features: &RequestFeatures,
        now: f64,
        deadline: f64,
        queue_depth: usize,
        capacity: usize,
    ) -> f64 {
        let wait = queue_depth as f64 / capacity.max(1) as f64
            * self.slack.predict_node(entry, features);
        self.slack.slack(entry, features, now, deadline) - wait
    }

    /// Admission gate for one arriving request; updates the counters.
    pub fn admit(
        &mut self,
        entry: NodeId,
        features: &RequestFeatures,
        now: f64,
        deadline: Option<f64>,
        queue_depth: usize,
        capacity: usize,
    ) -> AdmissionDecision {
        let predicted = deadline
            .map(|d| self.admission_slack(entry, features, now, d, queue_depth, capacity));
        let decision = self.admission.decide(predicted, queue_depth, capacity);
        match decision {
            AdmissionDecision::Admit => self.counters.on_admitted(),
            AdmissionDecision::ShedSlack { .. } => self.counters.on_shed_slack(),
            AdmissionDecision::ShedBackpressure { .. } => self.counters.on_shed_backpressure(),
        }
        decision
    }

    // ---- per-dispatch policy ----------------------------------------------

    /// Route a request to an instance of `node` (load/state-aware or the
    /// configured baseline policy).
    pub fn route(
        &mut self,
        req: u64,
        node: NodeId,
        stateful: bool,
        states: &[InstanceState],
    ) -> usize {
        self.router.route(req, node, stateful, states)
    }

    /// Drop a completed request's stateful bindings.
    pub fn release(&mut self, req: u64) {
        self.router.release(req);
    }

    /// Priority key for enqueueing at `node`: predicted slack under
    /// LeastSlack with a deadline, 0.0 otherwise (FIFO queues ignore it).
    pub fn enqueue_key(
        &self,
        node: NodeId,
        features: &RequestFeatures,
        now: f64,
        deadline: Option<f64>,
    ) -> f64 {
        match deadline {
            Some(d) if self.discipline == QueueDiscipline::LeastSlack => {
                self.slack.slack(node, features, now, d)
            }
            _ => 0.0,
        }
    }

    /// Raw slack for queue rekeying (no discipline gate — the caller only
    /// rekeys when [`TickOutcome::rekey`] said to).
    pub fn slack_value(
        &self,
        node: NodeId,
        features: &RequestFeatures,
        now: f64,
        deadline: Option<f64>,
    ) -> f64 {
        match deadline {
            Some(d) => self.slack.slack(node, features, now, d),
            None => 0.0,
        }
    }

    /// Record an observed (features → service) sample for the slack
    /// predictor.
    pub fn observe_service(&mut self, node: NodeId, features: &RequestFeatures, service: f64) {
        self.slack.observe(node, features, service);
    }

    // ---- telemetry passthrough --------------------------------------------

    pub fn on_enqueue(&mut self, node: NodeId) {
        self.telemetry.on_enqueue(node);
    }

    pub fn on_complete(&mut self, node: NodeId, service: f64) {
        self.telemetry.on_complete(node, service);
    }

    /// An enqueued item was discarded without executing (cancelled fork
    /// loser): rebalances the telemetry in-flight gauge only.
    pub fn on_cancelled(&mut self, node: NodeId) {
        self.telemetry.on_cancelled(node);
    }

    pub fn on_edge(&mut self, edge_idx: usize, node: NodeId) {
        self.telemetry.on_edge(edge_idx, node);
    }

    // ---- degradation -------------------------------------------------------

    pub fn degrade_enabled(&self) -> bool {
        self.degrade.enabled()
    }

    /// Service-time multiplier for a visit to a component with `knob`
    /// under the current overload level; counts degraded visits.
    pub fn service_factor(&self, knob: DegradeKnob) -> f64 {
        let f = degrade_service_factor(knob, self.degrade.level());
        if f != 1.0 {
            self.counters.on_degraded();
        }
        f
    }

    /// Should loop re-entry decisions at a `knob` component be clamped
    /// to the exit branch right now? (Pure query — callers count an
    /// [`SchedCounters::on_degraded`] only when a decision was actually
    /// overridden.)
    pub fn cap_iterations(&self, knob: DegradeKnob) -> bool {
        self.degrade.cap_iterations(knob)
    }

    // ---- the unified tick --------------------------------------------------

    /// One control-tick: (1) reassess the overload ladder from cluster
    /// utilization, (2) decide whether queues must be rekeyed, (3) run
    /// the telemetry-driven autoscaler when `realloc` inputs are given
    /// (None = reallocation disabled or unavailable on this backend).
    pub fn tick(
        &mut self,
        now: f64,
        utilization: f64,
        realloc: Option<(&PipelineGraph, &Profile, &[(ResourceKind, f64)])>,
    ) -> TickOutcome {
        let level = self.degrade.assess(utilization);
        let rekey = self.cfg.rekey_on_tick && self.discipline == QueueDiscipline::LeastSlack;
        let plan = match realloc {
            Some((graph, prior, budgets)) => {
                self.autoscaler
                    .maybe_rescale(now, graph, &self.telemetry, prior, budgets)
            }
            None => None,
        };
        TickOutcome { level, rekey, plan }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{SimConfig, SimWorld, SystemKind};
    use crate::spec::apps;
    use crate::workload::TraceConfig;

    fn plane(cfg: SchedConfig) -> ControlPlane {
        let g = apps::vanilla_rag();
        let priors: HashMap<NodeId, f64> = g.nodes.iter().map(|n| (n.id, 0.1)).collect();
        ControlPlane::new(
            &g,
            &priors,
            RoutingPolicy::LoadStateAware,
            QueueDiscipline::LeastSlack,
            cfg,
            10.0,
        )
    }

    fn feats() -> RequestFeatures {
        RequestFeatures { prompt_len: 60, gen_len: 40, k_docs: 200, complexity: 1 }
    }

    #[test]
    fn default_plane_is_dormant() {
        let mut p = plane(SchedConfig::default());
        assert!(!p.cfg.enabled());
        let entry = apps::vanilla_rag().node_by_name("retriever").unwrap().id;
        // Hopeless request: admitted anyway (admission off).
        let d = p.admit(entry, &feats(), 0.0, Some(0.0), 10_000, 8);
        assert!(d.admitted());
        let out = p.tick(1.0, 50.0, None);
        assert_eq!(out.level, OverloadLevel::Normal);
        assert!(!out.rekey);
        assert!(out.plan.is_none());
        assert_eq!(p.service_factor(DegradeKnob::ShrinkTopK), 1.0);
        assert_eq!(p.counters.snapshot().degraded, 0);
    }

    #[test]
    fn admission_slack_includes_queue_wait() {
        let p = plane(SchedConfig::overload_defense());
        let entry = apps::vanilla_rag().node_by_name("retriever").unwrap().id;
        let f = feats();
        let empty = p.admission_slack(entry, &f, 0.0, 2.0, 0, 8);
        let backed_up = p.admission_slack(entry, &f, 0.0, 2.0, 800, 8);
        assert!(empty > 0.0, "light load must leave positive slack, got {empty}");
        assert!(
            backed_up < empty - 1.0,
            "a 100-deep-per-slot queue must crush slack: {backed_up} vs {empty}"
        );
    }

    #[test]
    fn overloaded_plane_sheds_and_counts() {
        let mut p = plane(SchedConfig::overload_defense());
        let entry = apps::vanilla_rag().node_by_name("retriever").unwrap().id;
        let f = feats();
        assert!(p.admit(entry, &f, 0.0, Some(2.0), 0, 8).admitted());
        // Deep backlog: slack goes negative long before backpressure.
        let d = p.admit(entry, &f, 0.0, Some(2.0), 5_000, 8);
        assert!(matches!(d, AdmissionDecision::ShedSlack { .. }), "{d:?}");
        // No deadline: only backpressure applies.
        let d = p.admit(entry, &f, 0.0, None, 5_000, 8);
        assert!(matches!(d, AdmissionDecision::ShedBackpressure { .. }), "{d:?}");
        let snap = p.counters.snapshot();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.shed_slack, 1);
        assert_eq!(snap.shed_backpressure, 1);
        assert!((snap.shed_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn tick_publishes_ladder_and_requests_rekey() {
        let mut p = plane(SchedConfig::overload_defense());
        let out = p.tick(1.0, 3.0, None);
        assert_eq!(out.level, OverloadLevel::Severe);
        assert!(out.rekey);
        assert!(p.service_factor(DegradeKnob::SkipHop) < 1.0);
        assert!(p.cap_iterations(DegradeKnob::CapIterations));
        // Recovery.
        let out = p.tick(2.0, 0.1, None);
        assert_eq!(out.level, OverloadLevel::Normal);
        assert_eq!(p.service_factor(DegradeKnob::SkipHop), 1.0);
    }

    // ---- fixed-seed DES regression ----------------------------------------

    /// ~2× the retriever-bound capacity of V-RAG on the paper testbed
    /// (the LP places ~9 retriever instances × 8 slots / ~0.1 s ≈ 730/s).
    const OVERLOAD_RATE: f64 = 1440.0;
    const OVERLOAD_SEED: u64 = 0xA11;

    fn overload_cfg(sched: SchedConfig) -> SimConfig {
        let trace = TraceConfig {
            rate: OVERLOAD_RATE,
            n: 4000,
            slo: Some(2.0),
            ..TraceConfig::default()
        };
        let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, OVERLOAD_SEED);
        cfg.sched = sched;
        cfg
    }

    #[test]
    fn admission_and_degradation_cut_slo_violations_at_2x_overload() {
        // Plain EDF at 2× capacity: the backlog grows without bound, so a
        // large fraction of completions blow the 2 s SLO.
        let edf = SimWorld::simulate(apps::vanilla_rag(), overload_cfg(SchedConfig::default()));
        assert_eq!(edf.report.completed, 4000);
        assert_eq!(edf.report.shed, 0);
        assert!(
            edf.report.slo_violation_rate > 0.15,
            "2x overload should hurt plain EDF, rate {}",
            edf.report.slo_violation_rate
        );

        // EDF + admission + degradation: shed hopeless requests at the
        // door, shrink per-request work under the ladder — the survivors
        // overwhelmingly meet the SLO.
        let defended = SimWorld::simulate(
            apps::vanilla_rag(),
            overload_cfg(SchedConfig::overload_defense()),
        );
        assert!(
            defended.report.slo_violation_rate < edf.report.slo_violation_rate,
            "defense must strictly reduce violations: {} vs {}",
            defended.report.slo_violation_rate,
            edf.report.slo_violation_rate
        );
        assert!(defended.report.shed > 0, "2x overload must shed something");
        let snap = defended.report.sched.expect("defended run reports sched counters");
        assert_eq!(snap.shed(), defended.report.shed);
        assert_eq!(
            snap.offered(),
            4000,
            "every request passes the admission gate exactly once"
        );
        // Degradation engaged at some point during the burst.
        assert!(snap.degraded > 0, "overload should trigger the degrade ladder");
    }

    /// Decode-side overload fixture: a generator-only pipeline, so the
    /// admission gate sits directly on the pool the placement splits.
    fn gen_only() -> PipelineGraph {
        use crate::spec::{ComponentKind, PipelineBuilder, ResourceKind};
        let mut b = PipelineBuilder::new("gen-only");
        let gen = b
            .component("generator", ComponentKind::Generator)
            .resources(&[(ResourceKind::Gpu, 1.0)])
            .add();
        b.edge_from_source(gen, 1.0);
        b.edge_to_sink(gen, 1.0);
        b.build().expect("gen-only is valid")
    }

    #[test]
    fn placement_aware_admission_does_not_overshed_at_decode_side_overload() {
        use crate::profile::models::{GenBatching, GenPlacement, KvTransferModel};
        use crate::profile::profile_graph_gen;

        // Unit half: placement-aware priors reprice the generator (cached
        // prefill + transfer + decode < the collocated aggregate), so the
        // slack predictor promises MORE slack at the same queue depth —
        // the over-shedding a placement-blind prior would cause is the
        // regression this pins.
        let g = gen_only();
        let prior = profile_graph_gen(&g, 400, 0xBEEF, GenBatching::Continuous);
        let kv = KvTransferModel::default();
        let blind = prior.mean_service.clone();
        let aware = prior.placement_priors(GenPlacement::Disaggregated, &kv, 0.9);
        let entry = g.node_by_name("generator").unwrap().id;
        let mk_plane = |priors: &HashMap<NodeId, f64>| {
            ControlPlane::new(
                &g,
                priors,
                RoutingPolicy::LoadStateAware,
                QueueDiscipline::LeastSlack,
                SchedConfig::overload_defense(),
                10.0,
            )
        };
        let f = feats();
        let s_blind = mk_plane(&blind).admission_slack(entry, &f, 0.0, 2.0, 600, 128);
        let s_aware = mk_plane(&aware).admission_slack(entry, &f, 0.0, 2.0, 600, 128);
        assert!(
            s_aware > s_blind,
            "repriced generator must leave more predicted slack: {s_aware} vs {s_blind}"
        );

        // DES half: at ~2× the collocated generator capacity, the
        // disaggregated + prefix-cached arm (more effective capacity,
        // placement-aware slack keys via `SimWorld::new`) must shed
        // strictly less than the collocated arm on the same trace.
        let mk_cfg = |placement: GenPlacement, hit: f64| {
            let trace =
                TraceConfig { rate: 2000.0, n: 5000, slo: Some(2.0), ..TraceConfig::default() };
            let mut cfg = SimConfig::new(SystemKind::Harmonia, trace, 0xDEC0);
            cfg.sched = SchedConfig::overload_defense();
            cfg.gen_batching = GenBatching::Continuous;
            cfg.gen_placement = placement;
            cfg.kv_prefix_hit_rate = hit;
            cfg
        };
        let col = SimWorld::simulate(gen_only(), mk_cfg(GenPlacement::Collocated, 0.0));
        let dis = SimWorld::simulate(gen_only(), mk_cfg(GenPlacement::Disaggregated, 0.9));
        assert_eq!(col.report.completed + col.report.shed, 5000);
        assert_eq!(dis.report.completed + dis.report.shed, 5000);
        assert!(col.report.shed > 0, "2× decode-side overload must shed");
        assert!(dis.report.shed > 0, "the split arm is still overloaded at this rate");
        assert!(
            dis.report.shed < col.report.shed,
            "placement-aware admission must not over-shed: disagg {} vs collocated {}",
            dis.report.shed,
            col.report.shed
        );
    }

    #[test]
    fn overload_regression_is_deterministic() {
        let a = SimWorld::simulate(
            apps::vanilla_rag(),
            overload_cfg(SchedConfig::overload_defense()),
        );
        let b = SimWorld::simulate(
            apps::vanilla_rag(),
            overload_cfg(SchedConfig::overload_defense()),
        );
        assert_eq!(a.report.completed, b.report.completed);
        assert_eq!(a.report.shed, b.report.shed);
        assert_eq!(
            a.report.slo_violation_rate.to_bits(),
            b.report.slo_violation_rate.to_bits()
        );
    }
}
