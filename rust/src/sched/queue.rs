//! Deadline-aware queueing with predicted slack (§3.3.2).
//!
//! The controller maintains one online linear-regression model per
//! pipeline node mapping request features (prompt/generation lengths,
//! retrieved-doc counts) to that node's service time. Remaining execution
//! time for an in-flight request is the feature-predicted node times
//! weighted by expected remaining visits (from the graph's branch
//! structure). Slack = deadline − now − predicted remaining; queues pop
//! least-slack-first (EDF). Baselines use FIFO.
//!
//! [`PrioQueue`] is a binary heap keyed on `(key, fifo_seq)` — O(log n)
//! push/pop with a FIFO-stable tiebreak (equal keys pop in insertion
//! order), replacing the earlier O(n) linear-scan pop. [`PrioQueue::rekey`]
//! rebuilds the heap under fresh keys; the control plane uses it on its
//! tick because slack decays as time passes.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::profile::models::RequestFeatures;
use crate::spec::graph::{NodeId, PipelineGraph};
use crate::stats::OnlineLinReg;

/// Queue discipline for component queues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueDiscipline {
    Fifo,
    /// Least predicted slack first (Harmonia).
    LeastSlack,
}

/// Per-node latency predictors + expected-remaining-visit matrix.
#[derive(Debug)]
pub struct SlackPredictor {
    models: HashMap<NodeId, OnlineLinReg>,
    /// expected_visits[from][node]: expected visits of `node` for a
    /// request currently about to execute at `from` (includes `from`
    /// itself once). Latency semantics: within a fork group only the
    /// critical branch contributes (parallel siblings overlap in time,
    /// they don't add — `PipelineGraph::latency_edge_weights`), so the
    /// predicted remaining time is a critical-path estimate, not a sum
    /// of concurrent work.
    expected_visits: Vec<Vec<f64>>,
    /// Fallback mean service per node (profile prior) until warmed up.
    priors: HashMap<NodeId, f64>,
}

impl SlackPredictor {
    pub fn new(graph: &PipelineGraph, priors: &HashMap<NodeId, f64>) -> Self {
        let n = graph.nodes.len();
        // Critical-branch edge weights under the deploy-time priors
        // (identical to raw probabilities for fork-free graphs), computed
        // on the shared analysis bundle's fork index.
        let az = graph.analyze();
        let weights = az.latency_edge_weights(graph, priors);
        let mut expected_visits = vec![vec![0.0; n]; n];
        for start in 0..n {
            expected_visits[start] = visits_from(graph, &weights, NodeId(start));
        }
        SlackPredictor {
            models: graph.nodes.iter().map(|nd| (nd.id, OnlineLinReg::new(3, 0.995))).collect(),
            expected_visits,
            priors: priors.clone(),
        }
    }

    /// Record an observed (features → service time) sample for a node.
    pub fn observe(&mut self, node: NodeId, features: &RequestFeatures, service: f64) {
        if let Some(m) = self.models.get_mut(&node) {
            m.observe(&features.vector(), service);
        }
    }

    /// Predicted service time of one visit to `node`.
    pub fn predict_node(&self, node: NodeId, features: &RequestFeatures) -> f64 {
        let prior = self.priors.get(&node).copied().unwrap_or(0.05);
        match self.models.get(&node) {
            Some(m) if m.warmed_up() => m.predict(&features.vector()).max(0.0),
            _ => prior,
        }
    }

    /// Predicted remaining execution time for a request about to run at
    /// `at` (queueing excluded — the scheduler reasons about service).
    pub fn predict_remaining(&self, at: NodeId, features: &RequestFeatures) -> f64 {
        self.expected_visits[at.0]
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0.0)
            .map(|(i, &v)| v * self.predict_node(NodeId(i), features))
            .sum()
    }

    /// Slack for EDF priority: deadline − now − predicted remaining.
    pub fn slack(&self, at: NodeId, features: &RequestFeatures, now: f64, deadline: f64) -> f64 {
        deadline - now - self.predict_remaining(at, features)
    }
}

/// Expected visits of every node for a request starting at `start`
/// (fixed-point of v_j = [j==start] + Σ_i v_i γ_i w_{i,j}, sink absorbs).
/// `weights` are the per-edge latency weights (routing probabilities,
/// with fork groups reduced to their critical branch): starting inside a
/// non-critical branch still yields the correct downstream path, because
/// only the fork edges themselves are reweighted.
fn visits_from(graph: &PipelineGraph, weights: &[f64], start: NodeId) -> Vec<f64> {
    let n = graph.nodes.len();
    let mut v = vec![0.0f64; n];
    v[start.0] = 1.0;
    for _ in 0..10_000 {
        let mut nv = vec![0.0f64; n];
        nv[start.0] = 1.0;
        // Note: edges re-entering `start` are counted — those are loop
        // re-visits. Upstream nodes stay 0 (no flow reaches them from
        // `start`), so only the downstream/loop structure contributes.
        for (i, e) in graph.edges.iter().enumerate() {
            nv[e.to.0] += v[e.from.0] * graph.node(e.from).gamma * weights[i];
        }
        let diff: f64 = nv.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
        v = nv;
        if diff < 1e-12 {
            break;
        }
    }
    v
}

/// One heap entry; min-ordered on `(key, seq)` so equal-key entries pop
/// in insertion order (FIFO-stable tiebreak).
#[derive(Clone, Debug)]
struct HeapEntry<T> {
    key: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key.total_cmp(&other.key) == CmpOrdering::Equal && self.seq == other.seq
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // `BinaryHeap` is a max-heap; reverse both fields so `pop()`
        // yields the minimum (key, seq).
        other
            .key
            .total_cmp(&self.key)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

/// A priority queue of request work items keyed by slack (or enqueue
/// order under FIFO). Binary heap: O(log n) push/pop vs the previous
/// linear-scan pop, with FIFO-stable ordering on equal keys.
#[derive(Clone, Debug)]
pub struct PrioQueue<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    discipline: QueueDiscipline,
    fifo_seq: u64,
}

impl<T> PrioQueue<T> {
    pub fn new(discipline: QueueDiscipline) -> Self {
        PrioQueue { heap: BinaryHeap::new(), discipline, fifo_seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Push with a priority key (slack; ignored under FIFO).
    pub fn push(&mut self, key: f64, item: T) {
        self.fifo_seq += 1;
        let key = match self.discipline {
            QueueDiscipline::Fifo => self.fifo_seq as f64,
            QueueDiscipline::LeastSlack => key,
        };
        self.heap.push(HeapEntry { key, seq: self.fifo_seq, item });
    }

    /// Pop the minimum-key item (least slack / earliest enqueue).
    pub fn pop(&mut self) -> Option<T> {
        self.heap.pop().map(|e| e.item)
    }

    /// Re-key all entries (slack decays as time passes; the control
    /// plane's tick calls this so queued work is re-prioritized under the
    /// current clock). Rebuilds the heap; FIFO queues are untouched.
    pub fn rekey(&mut self, mut f: impl FnMut(&T) -> f64) {
        if self.discipline != QueueDiscipline::LeastSlack {
            return;
        }
        let entries: Vec<HeapEntry<T>> = self.heap.drain().collect();
        self.heap = entries
            .into_iter()
            .map(|mut e| {
                e.key = f(&e.item);
                e
            })
            .collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    fn features() -> RequestFeatures {
        RequestFeatures { prompt_len: 60, gen_len: 40, k_docs: 200, complexity: 1 }
    }

    #[test]
    fn remaining_decreases_along_pipeline() {
        let g = apps::vanilla_rag();
        let priors: HashMap<NodeId, f64> =
            g.nodes.iter().map(|n| (n.id, 0.1)).collect();
        let sp = SlackPredictor::new(&g, &priors);
        let f = features();
        let at_retr = sp.predict_remaining(g.node_by_name("retriever").unwrap().id, &f);
        let at_gen = sp.predict_remaining(g.node_by_name("generator").unwrap().id, &f);
        assert!(at_retr > at_gen, "{at_retr} vs {at_gen}");
    }

    #[test]
    fn predictor_learns_feature_dependence() {
        let g = apps::corrective_rag();
        let priors: HashMap<NodeId, f64> = g.nodes.iter().map(|n| (n.id, 0.1)).collect();
        let mut sp = SlackPredictor::new(&g, &priors);
        let grader = g.node_by_name("grader").unwrap().id;
        // Grader time = 0.02 + 8e-4 * k (the paper's §3.3.2 example:
        // grader time depends on retrieved-doc volume).
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..200 {
            let k = rng.range_i64(100, 300) as usize;
            let f = RequestFeatures { prompt_len: 60, gen_len: 40, k_docs: k, complexity: 1 };
            sp.observe(grader, &f, 0.02 + 8.0e-4 * k as f64);
        }
        let f100 = RequestFeatures { k_docs: 100, ..features() };
        let f300 = RequestFeatures { k_docs: 300, ..features() };
        let p100 = sp.predict_node(grader, &f100);
        let p300 = sp.predict_node(grader, &f300);
        assert!((p100 - 0.10).abs() < 0.02, "p100 {p100}");
        assert!((p300 - 0.26).abs() < 0.02, "p300 {p300}");
    }

    #[test]
    fn slack_accounts_for_recursion() {
        // S-RAG's expected remaining at the generator includes future
        // iterations (expected visits > 1 for upstream loop members).
        let g = apps::self_rag();
        let priors: HashMap<NodeId, f64> = g.nodes.iter().map(|n| (n.id, 0.1)).collect();
        let sp = SlackPredictor::new(&g, &priors);
        let f = features();
        let retr = g.node_by_name("retriever").unwrap().id;
        let rem = sp.predict_remaining(retr, &f);
        // 4 loop nodes × 0.1 × ~1.54 expected iterations ≈ 0.57; must
        // clearly exceed the single-pass sum of 0.4.
        assert!(rem > 0.45, "remaining {rem}");
    }

    #[test]
    fn remaining_time_is_critical_path_over_fork_groups() {
        // Hybrid: retriever (0.1) ∥ websearch (0.15) → generator (0.1).
        // Remaining-at-source must be max(branches) + generator, not the
        // sum of both branches.
        let g = apps::hybrid_rag();
        let priors: HashMap<NodeId, f64> = g
            .nodes
            .iter()
            .map(|n| {
                let m = match n.name.as_str() {
                    "retriever" => 0.10,
                    "websearch" => 0.15,
                    "generator" => 0.10,
                    _ => 0.0,
                };
                (n.id, m)
            })
            .collect();
        let sp = SlackPredictor::new(&g, &priors);
        let f = features();
        let at_source = sp.predict_remaining(g.source, &f);
        // Priors (not warmed models) answer: 0.15 + 0.10 = 0.25, and
        // strictly under the 0.35 branch sum.
        assert!((at_source - 0.25).abs() < 1e-9, "remaining {at_source}");
        // From inside the non-critical branch the whole downstream chain
        // still counts: retriever + generator.
        let retr = g.node_by_name("retriever").unwrap().id;
        let at_retr = sp.predict_remaining(retr, &f);
        assert!((at_retr - 0.20).abs() < 1e-9, "remaining {at_retr}");
    }

    #[test]
    fn prio_queue_least_slack_first() {
        let mut q = PrioQueue::new(QueueDiscipline::LeastSlack);
        q.push(2.0, "b");
        q.push(0.5, "a");
        q.push(9.0, "c");
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), Some("c"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn prio_queue_fifo_ignores_keys() {
        let mut q = PrioQueue::new(QueueDiscipline::Fifo);
        q.push(9.0, "first");
        q.push(0.1, "second");
        assert_eq!(q.pop(), Some("first"));
        assert_eq!(q.pop(), Some("second"));
    }

    #[test]
    fn equal_keys_pop_in_fifo_order() {
        // The heap's tiebreak: equal slack keys drain in insertion order
        // (no starvation/reordering among equally urgent requests).
        let mut q = PrioQueue::new(QueueDiscipline::LeastSlack);
        for i in 0..16u64 {
            q.push(0.0, i);
        }
        let drained: Vec<u64> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn heap_pops_min_over_many_random_keys() {
        let mut rng = crate::util::rng::Rng::new(42);
        let mut q = PrioQueue::new(QueueDiscipline::LeastSlack);
        let mut keys = Vec::new();
        for i in 0..500usize {
            let k = rng.uniform(-10.0, 10.0);
            keys.push(k);
            q.push(k, i);
        }
        let mut prev = f64::NEG_INFINITY;
        while let Some(i) = q.pop() {
            assert!(keys[i] >= prev, "heap order violated: {} after {prev}", keys[i]);
            prev = keys[i];
        }
    }

    #[test]
    fn rekey_reorders() {
        let mut q = PrioQueue::new(QueueDiscipline::LeastSlack);
        q.push(1.0, 10u64);
        q.push(2.0, 20u64);
        // After rekey, item 20 becomes most urgent.
        q.rekey(|&item| if item == 20 { 0.0 } else { 5.0 });
        assert_eq!(q.pop(), Some(20));
    }

    #[test]
    fn rekey_preserves_fifo_tiebreak() {
        let mut q = PrioQueue::new(QueueDiscipline::LeastSlack);
        q.push(3.0, 1u64);
        q.push(2.0, 2u64);
        q.push(1.0, 3u64);
        // Collapse every key to the same value: insertion order must win.
        q.rekey(|_| 0.0);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }
}
