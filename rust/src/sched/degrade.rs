//! Graduated degradation — trading small quality deltas for large
//! latency wins under overload (RAGO-style per-stage knobs).
//!
//! RAG pipelines have stage-local fidelity knobs that are invisible to a
//! generic scheduler: retrieval top-k, optional rerank/grader hops, and
//! refinement-loop iteration budgets. [`DegradePolicy`] watches cluster
//! utilization and exposes a three-level overload ladder; components
//! annotated with a [`DegradeKnob`] (see `spec::graph`) shed work
//! accordingly — the DES through
//! `profile::models::degrade_service_factor`, the live workers by
//! shrinking top-k / skipping the hop outright.
//!
//! The current level lives in a shared atomic cell ([`OverloadCell`]) so
//! live worker threads read it without locks, while the DES reads it
//! synchronously from the policy. **Disabled by default**: the level is
//! pinned at [`OverloadLevel::Normal`] and every factor is exactly 1.0,
//! so golden traces replay bit-identically.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

use crate::spec::graph::DegradeKnob;

/// The overload ladder. Ordering is meaningful: higher = more degraded.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum OverloadLevel {
    /// Full fidelity (the only level when the policy is disabled).
    #[default]
    Normal = 0,
    /// Utilization above `elevated_util`: shrink retrieval top-k.
    Elevated = 1,
    /// Utilization above `severe_util`: additionally skip optional hops
    /// and cap refinement loops.
    Severe = 2,
}

impl OverloadLevel {
    fn from_u8(v: u8) -> OverloadLevel {
        match v {
            2 => OverloadLevel::Severe,
            1 => OverloadLevel::Elevated,
            _ => OverloadLevel::Normal,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OverloadLevel::Normal => "normal",
            OverloadLevel::Elevated => "elevated",
            OverloadLevel::Severe => "severe",
        }
    }
}

/// Degradation knobs. **Disabled by default.**
#[derive(Clone, Copy, Debug)]
pub struct DegradeConfig {
    /// Master switch; `false` pins the level at `Normal`.
    pub enabled: bool,
    /// Utilization (queued + active work per concurrent slot, cluster
    /// wide) above which the ladder moves to `Elevated`.
    pub elevated_util: f64,
    /// Utilization above which the ladder moves to `Severe`.
    pub severe_util: f64,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        DegradeConfig { enabled: false, elevated_util: 1.25, severe_util: 2.5 }
    }
}

/// Shared, lock-free holder of the current overload level. Live worker
/// threads poll it on their hot path (one relaxed atomic load); the
/// controller's tick stores into it.
#[derive(Debug, Default)]
pub struct OverloadCell(AtomicU8);

impl OverloadCell {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn level(&self) -> OverloadLevel {
        OverloadLevel::from_u8(self.0.load(Ordering::Relaxed))
    }

    #[inline]
    pub fn set(&self, level: OverloadLevel) {
        self.0.store(level as u8, Ordering::Relaxed);
    }
}

/// The degradation policy object: maps utilization to an overload level
/// on each control tick and publishes it through the shared cell.
#[derive(Clone, Debug)]
pub struct DegradePolicy {
    pub cfg: DegradeConfig,
    cell: Arc<OverloadCell>,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        DegradePolicy::new(DegradeConfig::default())
    }
}

impl DegradePolicy {
    pub fn new(cfg: DegradeConfig) -> Self {
        DegradePolicy { cfg, cell: Arc::new(OverloadCell::new()) }
    }

    /// Build over an existing cell (the live path: workers hold the same
    /// `Arc` and see level changes without any controller round-trip).
    pub fn with_cell(cfg: DegradeConfig, cell: Arc<OverloadCell>) -> Self {
        DegradePolicy { cfg, cell }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The shared cell, for handing to live workers.
    pub fn cell(&self) -> Arc<OverloadCell> {
        self.cell.clone()
    }

    /// Current published level (`Normal` whenever disabled).
    pub fn level(&self) -> OverloadLevel {
        if !self.cfg.enabled {
            return OverloadLevel::Normal;
        }
        self.cell.level()
    }

    /// Control-tick step: map utilization to a level and publish it.
    pub fn assess(&mut self, utilization: f64) -> OverloadLevel {
        let level = if !self.cfg.enabled {
            OverloadLevel::Normal
        } else if utilization >= self.cfg.severe_util {
            OverloadLevel::Severe
        } else if utilization >= self.cfg.elevated_util {
            OverloadLevel::Elevated
        } else {
            OverloadLevel::Normal
        };
        self.cell.set(level);
        level
    }

    /// Should a sampled back-edge re-entry be clamped (loop forced to
    /// exit)? True only at `Severe` for `CapIterations` components.
    pub fn cap_iterations(&self, knob: DegradeKnob) -> bool {
        knob == DegradeKnob::CapIterations && self.level() == OverloadLevel::Severe
    }
}

/// Effective retrieval top-k for a component under the given level:
/// halves at `Elevated`, quarters at `Severe` (never below 1). Identity
/// for every knob other than `ShrinkTopK`.
pub fn degraded_top_k(k: usize, knob: DegradeKnob, level: OverloadLevel) -> usize {
    if knob != DegradeKnob::ShrinkTopK {
        return k;
    }
    match level {
        OverloadLevel::Normal => k,
        OverloadLevel::Elevated => (k / 2).max(1),
        OverloadLevel::Severe => (k / 4).max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_policy_never_degrades() {
        let mut p = DegradePolicy::default();
        assert!(!p.enabled(), "degradation must default off");
        assert_eq!(p.assess(100.0), OverloadLevel::Normal);
        assert_eq!(p.level(), OverloadLevel::Normal);
        assert!(!p.cap_iterations(DegradeKnob::CapIterations));
    }

    #[test]
    fn ladder_follows_utilization() {
        let cfg = DegradeConfig { enabled: true, ..DegradeConfig::default() };
        let mut p = DegradePolicy::new(cfg);
        assert_eq!(p.assess(0.5), OverloadLevel::Normal);
        assert_eq!(p.assess(1.5), OverloadLevel::Elevated);
        assert_eq!(p.assess(3.0), OverloadLevel::Severe);
        assert!(p.cap_iterations(DegradeKnob::CapIterations));
        assert!(!p.cap_iterations(DegradeKnob::ShrinkTopK));
        // Recovery: the ladder steps back down.
        assert_eq!(p.assess(0.2), OverloadLevel::Normal);
    }

    #[test]
    fn cell_is_shared_with_workers() {
        let cfg = DegradeConfig { enabled: true, ..DegradeConfig::default() };
        let mut p = DegradePolicy::new(cfg);
        let worker_view = p.cell();
        assert_eq!(worker_view.level(), OverloadLevel::Normal);
        p.assess(5.0);
        assert_eq!(worker_view.level(), OverloadLevel::Severe);
    }

    #[test]
    fn top_k_shrinks_with_level() {
        assert_eq!(degraded_top_k(8, DegradeKnob::ShrinkTopK, OverloadLevel::Normal), 8);
        assert_eq!(degraded_top_k(8, DegradeKnob::ShrinkTopK, OverloadLevel::Elevated), 4);
        assert_eq!(degraded_top_k(8, DegradeKnob::ShrinkTopK, OverloadLevel::Severe), 2);
        // Never below 1; other knobs untouched.
        assert_eq!(degraded_top_k(1, DegradeKnob::ShrinkTopK, OverloadLevel::Severe), 1);
        assert_eq!(degraded_top_k(8, DegradeKnob::SkipHop, OverloadLevel::Severe), 8);
        assert_eq!(degraded_top_k(8, DegradeKnob::None, OverloadLevel::Severe), 8);
    }
}
