//! The **scheduling layer**: a clock-agnostic overload control plane
//! shared by the discrete-event simulator and the live controller.
//!
//! Patchwork's third pillar is online scheduling that minimizes SLO
//! violations through strategic prioritization — but queue reordering
//! (EDF) is only half of an overload story. Once the backlog exceeds the
//! deadline budget, *every* order loses; the remaining levers act before
//! and around the queue:
//!
//! * [`queue`] — deadline-aware queueing: [`queue::SlackPredictor`]
//!   (per-node online regression → predicted remaining time) and
//!   [`queue::PrioQueue`], a binary heap on `(slack, fifo_seq)` with a
//!   FIFO-stable tiebreak.
//! * [`admission`] — admission control: shed requests whose predicted
//!   slack is already negative at arrival, plus queue-depth backpressure
//!   (Harmonia-style admission-time decisions).
//! * [`degrade`] — graduated degradation: a utilization-driven overload
//!   ladder that shrinks retrieval top-k, skips optional quality hops,
//!   and caps refinement loops on components annotated with
//!   `spec::DegradeKnob` (RAGO-style per-stage knobs).
//! * [`plane`] — [`plane::ControlPlane`]: routing + slack + telemetry +
//!   autoscaling + admission + degradation behind one API, with a
//!   unified tick (admission ladder → rekey → autoscale). Every method
//!   takes `now: f64` seconds, so the DES drives it with virtual time
//!   and the live controller with `util::clock::WallClock`.
//!
//! **Defaults preserve history**: admission, degradation, and rekeying
//! all ship disabled, and a default-configured plane reproduces the
//! pre-refactor scheduler decisions bit-for-bit on deadline-carrying
//! traces (`golden_trace.rs` pins this). One deliberate exception: the
//! heap's FIFO tiebreak replaces the old linear scan's
//! insertion-shuffled order among *exactly equal* keys — observable
//! only under LeastSlack with no deadlines (every key 0.0), where the
//! old order was an artifact of `swap_remove`, not a policy. The
//! `fig11b_overload` bench sweeps the policy ladder (FIFO / EDF /
//! EDF+admission / EDF+admission+degrade) across offered load.

pub mod admission;
pub mod degrade;
pub mod plane;
pub mod queue;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionDecision};
pub use degrade::{degraded_top_k, DegradeConfig, DegradePolicy, OverloadCell, OverloadLevel};
pub use plane::{ControlPlane, SchedConfig, TickOutcome};
pub use queue::{PrioQueue, QueueDiscipline, SlackPredictor};
