//! Admission control — the overload defense that acts *before* queueing.
//!
//! EDF reordering alone cannot save an SLO once the backlog exceeds the
//! deadline budget: every queued request is already late, and serving
//! them in a smarter order only chooses *which* requests violate.
//! Harmonia-style admission makes the decision at arrival time instead:
//! a request whose predicted slack is already negative when it enters the
//! system (deadline − predicted service − predicted queue wait < 0) is
//! shed immediately, and queue-depth backpressure bounds the backlog even
//! for requests without deadlines. Shed requests cost one prediction
//! instead of a full pipeline pass, so capacity is spent only on requests
//! that can still meet their SLO — goodput instead of throughput.
//!
//! Everything here is pure arithmetic over plain state: no clocks, no
//! channels. The caller (DES or live controller) supplies `now`, the
//! predicted slack, and the queue picture; see [`crate::sched::plane`].

/// Admission-control knobs. **Disabled by default** — the stock control
/// plane admits everything, and golden traces replay unchanged.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Master switch; `false` admits every request unconditionally.
    pub enabled: bool,
    /// Shed when predicted slack at admission falls below this (seconds).
    /// 0.0 = shed exactly when the deadline is already unattainable.
    pub min_slack: f64,
    /// Queue-depth backpressure: shed when the entry component's queued
    /// work exceeds `backpressure_depth ×` its concurrent capacity
    /// (slots). Guards no-deadline traffic and caps worst-case backlog.
    pub backpressure_depth: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { enabled: false, min_slack: 0.0, backpressure_depth: 4.0 }
    }
}

/// Outcome of one admission decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdmissionDecision {
    Admit,
    /// Predicted slack below `min_slack`: the deadline is unattainable.
    ShedSlack { predicted_slack: f64 },
    /// Entry queue above the backpressure threshold.
    ShedBackpressure { queue_depth: usize },
}

impl AdmissionDecision {
    pub fn admitted(&self) -> bool {
        matches!(self, AdmissionDecision::Admit)
    }
}

/// The admission policy object. Stateless beyond its config; counters
/// live in [`crate::metrics::SchedCounters`] (attached by the plane).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionController {
    pub cfg: AdmissionConfig,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController { cfg }
    }

    /// Decide admission for one arriving request.
    ///
    /// * `predicted_slack` — deadline − now − predicted (service + queue
    ///   wait); `None` when the request carries no deadline (then only
    ///   backpressure applies).
    /// * `queue_depth` / `capacity` — entry component's queued work and
    ///   total concurrent slots.
    ///
    /// Invariant (pinned by the property test below): a request with
    /// non-negative predicted slack and a queue below the backpressure
    /// threshold is **always** admitted.
    pub fn decide(
        &self,
        predicted_slack: Option<f64>,
        queue_depth: usize,
        capacity: usize,
    ) -> AdmissionDecision {
        if !self.cfg.enabled {
            return AdmissionDecision::Admit;
        }
        if let Some(s) = predicted_slack {
            if s < self.cfg.min_slack {
                return AdmissionDecision::ShedSlack { predicted_slack: s };
            }
        }
        let limit = self.cfg.backpressure_depth * capacity.max(1) as f64;
        if queue_depth as f64 > limit {
            return AdmissionDecision::ShedBackpressure { queue_depth };
        }
        AdmissionDecision::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn disabled_admits_everything() {
        let a = AdmissionController::default();
        assert!(!a.cfg.enabled, "admission must default off");
        // Hopeless slack and a huge backlog: still admitted when disabled.
        assert_eq!(a.decide(Some(-100.0), 1_000_000, 1), AdmissionDecision::Admit);
    }

    #[test]
    fn sheds_negative_slack_and_deep_queues() {
        let a = AdmissionController::new(AdmissionConfig {
            enabled: true,
            ..AdmissionConfig::default()
        });
        match a.decide(Some(-0.1), 0, 8) {
            AdmissionDecision::ShedSlack { predicted_slack } => {
                assert!((predicted_slack + 0.1).abs() < 1e-12)
            }
            other => panic!("expected ShedSlack, got {other:?}"),
        }
        // depth 33 > 4.0 × 8 slots.
        match a.decide(None, 33, 8) {
            AdmissionDecision::ShedBackpressure { queue_depth } => assert_eq!(queue_depth, 33),
            other => panic!("expected ShedBackpressure, got {other:?}"),
        }
        assert_eq!(a.decide(Some(0.5), 32, 8), AdmissionDecision::Admit);
    }

    #[test]
    fn never_sheds_healthy_requests_property() {
        // The control-plane invariant: admission never sheds while
        // predicted slack ≥ min_slack and the queue is below the
        // backpressure threshold — whatever the config.
        property("healthy requests always admitted", 500, |g| {
            let cfg = AdmissionConfig {
                enabled: true,
                min_slack: g.f64(-1.0, 1.0),
                backpressure_depth: g.f64(0.5, 16.0),
            };
            let a = AdmissionController::new(cfg);
            let capacity = g.usize(1, 512);
            let limit = (cfg.backpressure_depth * capacity as f64).floor().max(0.0) as usize;
            let queue_depth = g.usize(0, limit);
            let slack = if g.bool() {
                Some(cfg.min_slack + g.f64(0.0, 10.0))
            } else {
                None // no deadline: slack rule cannot apply
            };
            assert_eq!(
                a.decide(slack, queue_depth, capacity),
                AdmissionDecision::Admit,
                "healthy request shed: slack {slack:?}, depth {queue_depth}/{capacity}"
            );
        });
    }
}
