//! Small LP modeling layer over the simplex core: named variables,
//! incremental constraint building — the shape of API the allocator uses
//! (mirrors how the paper would call Gurobi).

use super::simplex::{self, LpSolution, RowSense};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sense {
    Le,
    Eq,
    Ge,
}

impl From<Sense> for RowSense {
    fn from(s: Sense) -> RowSense {
        match s {
            Sense::Le => RowSense::Le,
            Sense::Eq => RowSense::Eq,
            Sense::Ge => RowSense::Ge,
        }
    }
}

/// Variable handle returned by [`LpModel::var`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Var(pub usize);

#[derive(Clone, Debug)]
pub struct Constraint {
    pub terms: Vec<(Var, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// Incrementally-built LP: max Σ obj·x subject to constraints, x ≥ 0.
#[derive(Clone, Debug, Default)]
pub struct LpModel {
    names: Vec<String>,
    obj: Vec<f64>,
    constraints: Vec<Constraint>,
}

impl LpModel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a variable with objective coefficient `obj` (x ≥ 0 implicit).
    pub fn var(&mut self, name: impl Into<String>, obj: f64) -> Var {
        self.names.push(name.into());
        self.obj.push(obj);
        Var(self.names.len() - 1)
    }

    pub fn n_vars(&self) -> usize {
        self.names.len()
    }

    pub fn n_constraints(&self) -> usize {
        self.constraints.len()
    }

    pub fn name(&self, v: Var) -> &str {
        &self.names[v.0]
    }

    /// Add Σ coeff·var  sense  rhs.
    pub fn constrain(&mut self, terms: Vec<(Var, f64)>, sense: Sense, rhs: f64) {
        debug_assert!(terms.iter().all(|(v, _)| v.0 < self.names.len()));
        self.constraints.push(Constraint { terms, sense, rhs });
    }

    /// Solve with the in-crate simplex.
    pub fn solve(&self) -> Result<LpSolution, simplex::LpError> {
        let n = self.obj.len();
        let m = self.constraints.len();
        let mut a = vec![0.0f64; m * n];
        let mut senses = Vec::with_capacity(m);
        let mut b = Vec::with_capacity(m);
        for (i, cst) in self.constraints.iter().enumerate() {
            for &(v, coef) in &cst.terms {
                a[i * n + v.0] += coef;
            }
            senses.push(cst.sense.into());
            b.push(cst.rhs);
        }
        simplex::solve(&self.obj, &a, &senses, &b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::simplex::Status;

    #[test]
    fn model_roundtrip() {
        let mut m = LpModel::new();
        let x = m.var("x", 3.0);
        let y = m.var("y", 2.0);
        m.constrain(vec![(x, 1.0), (y, 1.0)], Sense::Le, 4.0);
        m.constrain(vec![(x, 1.0), (y, 3.0)], Sense::Le, 6.0);
        let sol = m.solve().unwrap();
        assert_eq!(sol.status, Status::Optimal);
        assert!((sol.objective - 12.0).abs() < 1e-6);
        assert_eq!(m.name(x), "x");
        assert_eq!(m.n_vars(), 2);
        assert_eq!(m.n_constraints(), 2);
    }

    #[test]
    fn duplicate_terms_accumulate() {
        let mut m = LpModel::new();
        let x = m.var("x", 1.0);
        // x + x <= 4  →  2x <= 4  →  x <= 2.
        m.constrain(vec![(x, 1.0), (x, 1.0)], Sense::Le, 4.0);
        let sol = m.solve().unwrap();
        assert!((sol.x[0] - 2.0).abs() < 1e-6);
    }
}
