//! Dense two-phase primal simplex.
//!
//! Solves  max cᵀx  s.t.  A x {<=,=,>=} b,  x >= 0.
//!
//! Phase 1 drives artificial variables out of the basis; phase 2 optimizes
//! the real objective. Bland's rule is used as an anti-cycling fallback
//! after a pivot-count threshold; otherwise Dantzig's rule (most negative
//! reduced cost) for speed.

/// Inequality sense of one constraint row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowSense {
    Le,
    Eq,
    Ge,
}

/// Solver status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    Optimal,
    Infeasible,
    Unbounded,
}

#[derive(Clone, Debug)]
pub struct LpSolution {
    pub status: Status,
    /// Optimal primal values (length = number of structural variables).
    pub x: Vec<f64>,
    pub objective: f64,
    /// Simplex pivots performed (for the Fig. 12 scalability study).
    pub pivots: usize,
}

#[derive(Debug)]
pub enum LpError {
    Dimension(String),
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Dimension(s) => write!(f, "dimension error: {s}"),
        }
    }
}

impl std::error::Error for LpError {}

const EPS: f64 = 1e-9;

/// Solve max cᵀx s.t. rows; x >= 0.
///
/// `a` is row-major with `cols = c.len()` columns.
pub fn solve(
    c: &[f64],
    a: &[f64],
    senses: &[RowSense],
    b: &[f64],
) -> Result<LpSolution, LpError> {
    let n = c.len();
    let m = senses.len();
    if a.len() != n * m || b.len() != m {
        return Err(LpError::Dimension(format!(
            "a={} expected {} (m={m} n={n}), b={}",
            a.len(),
            n * m,
            b.len()
        )));
    }

    // Normalize to b >= 0 by flipping rows.
    let mut rows: Vec<Vec<f64>> = (0..m).map(|i| a[i * n..(i + 1) * n].to_vec()).collect();
    let mut senses = senses.to_vec();
    let mut b = b.to_vec();
    for i in 0..m {
        if b[i] < 0.0 {
            for v in rows[i].iter_mut() {
                *v = -*v;
            }
            b[i] = -b[i];
            senses[i] = match senses[i] {
                RowSense::Le => RowSense::Ge,
                RowSense::Ge => RowSense::Le,
                RowSense::Eq => RowSense::Eq,
            };
        }
    }

    // Column layout: [structural n][slack/surplus s][artificial t].
    let n_slack = senses
        .iter()
        .filter(|s| matches!(s, RowSense::Le | RowSense::Ge))
        .count();
    let n_art = senses
        .iter()
        .filter(|s| matches!(s, RowSense::Eq | RowSense::Ge))
        .count();
    let total = n + n_slack + n_art;

    // Tableau: m rows × total cols, plus rhs.
    let mut t = vec![0.0f64; m * total];
    let mut rhs = b.clone();
    let mut basis = vec![0usize; m];
    let mut s_idx = n;
    let mut a_idx = n + n_slack;
    for i in 0..m {
        for j in 0..n {
            t[i * total + j] = rows[i][j];
        }
        match senses[i] {
            RowSense::Le => {
                t[i * total + s_idx] = 1.0;
                basis[i] = s_idx;
                s_idx += 1;
            }
            RowSense::Ge => {
                t[i * total + s_idx] = -1.0;
                s_idx += 1;
                t[i * total + a_idx] = 1.0;
                basis[i] = a_idx;
                a_idx += 1;
            }
            RowSense::Eq => {
                t[i * total + a_idx] = 1.0;
                basis[i] = a_idx;
                a_idx += 1;
            }
        }
    }

    let mut pivots = 0usize;

    // Phase 1: minimize sum of artificials == max(-sum).
    if n_art > 0 {
        let mut obj = vec![0.0f64; total];
        for j in (n + n_slack)..total {
            obj[j] = -1.0;
        }
        let (status, z) = simplex_core(&mut t, &mut rhs, &mut basis, &obj, total, m, &mut pivots);
        if status == Status::Unbounded {
            // Phase-1 objective is bounded by 0; unbounded means a bug.
            return Ok(LpSolution { status: Status::Infeasible, x: vec![0.0; n], objective: 0.0, pivots });
        }
        if z < -1e-7 {
            return Ok(LpSolution { status: Status::Infeasible, x: vec![0.0; n], objective: 0.0, pivots });
        }
        // Drive any remaining artificial basics out (degenerate rows).
        for i in 0..m {
            if basis[i] >= n + n_slack {
                // Find a non-artificial column with nonzero coefficient.
                if let Some(j) = (0..n + n_slack).find(|&j| t[i * total + j].abs() > EPS) {
                    pivot(&mut t, &mut rhs, &mut basis, total, m, i, j);
                    pivots += 1;
                }
                // Otherwise the row is all-zero (redundant) — harmless.
            }
        }
    }

    // Phase 2: maximize cᵀx, artificial columns frozen at zero.
    let mut obj = vec![0.0f64; total];
    obj[..n].copy_from_slice(c);
    // Zero out artificial columns so they never re-enter.
    for i in 0..m {
        for j in (n + n_slack)..total {
            if basis[i] != j {
                t[i * total + j] = 0.0;
            }
        }
    }
    let (status, z) = simplex_core(&mut t, &mut rhs, &mut basis, &obj, n + n_slack, m, &mut pivots);

    let mut x = vec![0.0f64; n];
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] = rhs[i];
        }
    }
    Ok(LpSolution { status, x, objective: z, pivots })
}

/// Run simplex on the tableau with entering columns restricted to
/// `0..allowed_cols`. Returns (status, objective value).
fn simplex_core(
    t: &mut [f64],
    rhs: &mut [f64],
    basis: &mut [usize],
    obj: &[f64],
    allowed_cols: usize,
    m: usize,
    pivots: &mut usize,
) -> (Status, f64) {
    let total = obj.len();
    // Reduced costs maintained implicitly: z_j - c_j = c_B B^-1 A_j - c_j.
    let max_pivots_dantzig = 20_000;
    loop {
        // Compute reduced costs for allowed columns.
        let mut entering: Option<usize> = None;
        let mut best = 1e-7; // strictly-improving tolerance
        let bland = *pivots > max_pivots_dantzig;
        for j in 0..allowed_cols {
            if basis.contains(&j) {
                continue;
            }
            let mut zj = 0.0;
            for i in 0..m {
                zj += obj[basis[i]] * t[i * total + j];
            }
            let rc = obj[j] - zj; // improvement if > 0 (maximization)
            if bland {
                if rc > 1e-7 {
                    entering = Some(j);
                    break;
                }
            } else if rc > best {
                best = rc;
                entering = Some(j);
            }
        }
        let Some(e) = entering else {
            // Optimal.
            let z: f64 = (0..m).map(|i| obj[basis[i]] * rhs[i]).sum();
            return (Status::Optimal, z);
        };
        // Ratio test.
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            let aie = t[i * total + e];
            if aie > EPS {
                let ratio = rhs[i] / aie;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map_or(true, |l| basis[i] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else {
            return (Status::Unbounded, f64::INFINITY);
        };
        pivot(t, rhs, basis, total, m, l, e);
        *pivots += 1;
        if *pivots > 200_000 {
            // Safety valve; should never trigger on our problem sizes.
            let z: f64 = (0..m).map(|i| obj[basis[i]] * rhs[i]).sum();
            return (Status::Optimal, z);
        }
    }
}

fn pivot(t: &mut [f64], rhs: &mut [f64], basis: &mut [usize], total: usize, m: usize, l: usize, e: usize) {
    let piv = t[l * total + e];
    debug_assert!(piv.abs() > EPS);
    let inv = 1.0 / piv;
    for j in 0..total {
        t[l * total + j] *= inv;
    }
    rhs[l] *= inv;
    for i in 0..m {
        if i == l {
            continue;
        }
        let f = t[i * total + e];
        if f.abs() > EPS {
            for j in 0..total {
                t[i * total + j] -= f * t[l * total + j];
            }
            rhs[i] -= f * rhs[l];
            // Clamp tiny negatives from roundoff.
            if rhs[i] < 0.0 && rhs[i] > -1e-9 {
                rhs[i] = 0.0;
            }
        }
    }
    basis[l] = e;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn simple_2d_max() {
        // max 3x + 2y s.t. x + y <= 4; x + 3y <= 6 → x=4, y=0, z=12.
        let sol = solve(
            &[3.0, 2.0],
            &[1.0, 1.0, 1.0, 3.0],
            &[RowSense::Le, RowSense::Le],
            &[4.0, 6.0],
        )
        .unwrap();
        assert_eq!(sol.status, Status::Optimal);
        assert_close(sol.objective, 12.0);
        assert_close(sol.x[0], 4.0);
        assert_close(sol.x[1], 0.0);
    }

    #[test]
    fn classic_production_problem() {
        // max 5x + 4y s.t. 6x + 4y <= 24; x + 2y <= 6 → x=3, y=1.5, z=21.
        let sol = solve(
            &[5.0, 4.0],
            &[6.0, 4.0, 1.0, 2.0],
            &[RowSense::Le, RowSense::Le],
            &[24.0, 6.0],
        )
        .unwrap();
        assert_close(sol.objective, 21.0);
        assert_close(sol.x[0], 3.0);
        assert_close(sol.x[1], 1.5);
    }

    #[test]
    fn equality_constraints() {
        // max x + y s.t. x + y = 5; x <= 3 → z=5 (e.g. x=3,y=2).
        let sol = solve(
            &[1.0, 1.0],
            &[1.0, 1.0, 1.0, 0.0],
            &[RowSense::Eq, RowSense::Le],
            &[5.0, 3.0],
        )
        .unwrap();
        assert_eq!(sol.status, Status::Optimal);
        assert_close(sol.objective, 5.0);
        assert!(sol.x[0] <= 3.0 + 1e-9);
    }

    #[test]
    fn ge_constraints_phase1() {
        // max -x s.t. x >= 2 → x=2, z=-2.
        let sol = solve(&[-1.0], &[1.0], &[RowSense::Ge], &[2.0]).unwrap();
        assert_eq!(sol.status, Status::Optimal);
        assert_close(sol.x[0], 2.0);
        assert_close(sol.objective, -2.0);
    }

    #[test]
    fn detects_infeasible() {
        // x <= 1 and x >= 2.
        let sol = solve(
            &[1.0],
            &[1.0, 1.0],
            &[RowSense::Le, RowSense::Ge],
            &[1.0, 2.0],
        )
        .unwrap();
        assert_eq!(sol.status, Status::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        // max x with no constraint binding x.
        let sol = solve(&[1.0, 0.0], &[0.0, 1.0], &[RowSense::Le], &[1.0]).unwrap();
        assert_eq!(sol.status, Status::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // max x s.t. -x <= -2 (i.e. x >= 2), x <= 5 → x = 5.
        let sol = solve(
            &[1.0],
            &[-1.0, 1.0],
            &[RowSense::Le, RowSense::Le],
            &[-2.0, 5.0],
        )
        .unwrap();
        assert_eq!(sol.status, Status::Optimal);
        assert_close(sol.x[0], 5.0);
    }

    #[test]
    fn degenerate_problem_terminates() {
        // Multiple constraints intersecting at the same vertex.
        let sol = solve(
            &[1.0, 1.0],
            &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            &[RowSense::Le, RowSense::Le, RowSense::Le],
            &[1.0, 1.0, 2.0],
        )
        .unwrap();
        assert_eq!(sol.status, Status::Optimal);
        assert_close(sol.objective, 2.0);
    }

    #[test]
    fn solution_satisfies_constraints_property() {
        // Random feasible-by-construction LPs: verify feasibility and that
        // the reported objective matches cᵀx.
        property("lp feasibility", 60, |g| {
            let n = g.usize(1, 6);
            let m = g.usize(1, 6);
            let c: Vec<f64> = (0..n).map(|_| g.f64(-5.0, 5.0)).collect();
            let mut a = vec![0.0; m * n];
            for v in a.iter_mut() {
                *v = g.f64(0.0, 3.0); // nonnegative A with Le rows => bounded
            }
            let b: Vec<f64> = (0..m).map(|_| g.f64(0.5, 10.0)).collect();
            let senses = vec![RowSense::Le; m];
            let sol = solve(&c, &a, &senses, &b).unwrap();
            // x = 0 is feasible => never infeasible. Could be unbounded if a
            // column is all-zero with positive c.
            if sol.status != Status::Optimal {
                return;
            }
            for i in 0..m {
                let lhs: f64 = (0..n).map(|j| a[i * n + j] * sol.x[j]).sum();
                assert!(lhs <= b[i] + 1e-6, "row {i}: {lhs} > {}", b[i]);
            }
            for &xj in &sol.x {
                assert!(xj >= -1e-9);
            }
            let z: f64 = c.iter().zip(&sol.x).map(|(ci, xi)| ci * xi).sum();
            assert!((z - sol.objective).abs() < 1e-6);
        });
    }

    #[test]
    fn optimality_vs_exhaustive_vertices_2d() {
        // For 2-var LPs, check against a grid search upper bound.
        property("lp 2d optimality", 40, |g| {
            let c = [g.f64(0.1, 4.0), g.f64(0.1, 4.0)];
            let a = [
                g.f64(0.2, 2.0),
                g.f64(0.2, 2.0),
                g.f64(0.2, 2.0),
                g.f64(0.2, 2.0),
            ];
            let b = [g.f64(1.0, 8.0), g.f64(1.0, 8.0)];
            let sol = solve(&c, &a, &[RowSense::Le, RowSense::Le], &b).unwrap();
            assert_eq!(sol.status, Status::Optimal);
            // Grid-search feasible region; LP optimum must dominate.
            let mut best = 0.0f64;
            let steps = 60;
            let xmax = (b[0] / a[0]).min(b[1] / a[2]);
            let ymax = (b[0] / a[1]).min(b[1] / a[3]);
            for i in 0..=steps {
                for j in 0..=steps {
                    let x = xmax * i as f64 / steps as f64;
                    let y = ymax * j as f64 / steps as f64;
                    if a[0] * x + a[1] * y <= b[0] && a[2] * x + a[3] * y <= b[1] {
                        best = best.max(c[0] * x + c[1] * y);
                    }
                }
            }
            assert!(
                sol.objective >= best - 1e-6,
                "simplex {} < grid {best}",
                sol.objective
            );
        });
    }
}
