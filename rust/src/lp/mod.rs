//! Linear programming substrate — the Gurobi substitute.
//!
//! The paper solves its resource-allocation formulation (Fig. 8) with
//! Gurobi; that is proprietary and unavailable here, so we implement a
//! dense two-phase primal simplex ([`simplex`]) behind a small modeling
//! API ([`model`]). Problem sizes are modest (a RAG graph has tens of
//! nodes; Fig. 12 scales the *cluster*, which enters as constraint
//! coefficients, not variables), so dense simplex comfortably reproduces
//! the paper's 3.8–31.3 ms solve times.

pub mod model;
pub mod simplex;

pub use model::{Constraint, LpModel, Sense};
pub use simplex::{solve, LpError, LpSolution, Status};
