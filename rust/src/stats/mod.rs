//! Statistics substrate: online estimators used by the runtime controller
//! (telemetry smoothing, slack prediction) and by the metrics layer.

pub mod ewma;
pub mod linreg;
pub mod percentile;

pub use ewma::Ewma;
pub use linreg::OnlineLinReg;
pub use percentile::{percentile, Histogram};
