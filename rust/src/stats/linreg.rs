//! Online multivariate linear regression via recursive least squares with
//! exponential forgetting.
//!
//! This is the paper's §3.3.2 slack predictor: "the runtime maintains
//! online linear regression models that map upstream execution features —
//! such as the number of retrieved documents or token counts — to
//! downstream component latencies". RLS gives O(d²) updates with no stored
//! history, cheap enough to run per completed stage.

/// RLS estimator for y ≈ wᵀx + b with forgetting factor `lambda` (≤ 1).
#[derive(Clone, Debug)]
pub struct OnlineLinReg {
    /// Dimensionality including the bias term.
    d: usize,
    /// Weights, last element is the bias.
    w: Vec<f64>,
    /// Inverse covariance P (d×d, row-major).
    p: Vec<f64>,
    lambda: f64,
    n: u64,
}

impl OnlineLinReg {
    /// `features`: number of input features (bias added internally).
    pub fn new(features: usize, lambda: f64) -> Self {
        let d = features + 1;
        let mut p = vec![0.0; d * d];
        for i in 0..d {
            p[i * d + i] = 1e3; // large prior variance => fast initial adaptation
        }
        OnlineLinReg { d, w: vec![0.0; d], p, lambda, n: 0 }
    }

    fn aug(&self, x: &[f64]) -> Vec<f64> {
        let mut v = Vec::with_capacity(self.d);
        v.extend_from_slice(x);
        v.push(1.0);
        v
    }

    /// Observe (x, y) and update the model.
    pub fn observe(&mut self, x: &[f64], y: f64) {
        assert_eq!(x.len() + 1, self.d, "feature arity mismatch");
        let xa = self.aug(x);
        let d = self.d;
        // k = P x / (lambda + xᵀ P x)
        let mut px = vec![0.0; d];
        for i in 0..d {
            let mut s = 0.0;
            for j in 0..d {
                s += self.p[i * d + j] * xa[j];
            }
            px[i] = s;
        }
        let denom = self.lambda + xa.iter().zip(&px).map(|(a, b)| a * b).sum::<f64>();
        let err = y - self.predict_aug(&xa);
        for i in 0..d {
            self.w[i] += px[i] / denom * err;
        }
        // P = (P - k xᵀ P) / lambda
        for i in 0..d {
            for j in 0..d {
                self.p[i * d + j] = (self.p[i * d + j] - px[i] * px[j] / denom) / self.lambda;
            }
        }
        self.n += 1;
    }

    fn predict_aug(&self, xa: &[f64]) -> f64 {
        self.w.iter().zip(xa).map(|(w, x)| w * x).sum()
    }

    /// Predict y for features x.
    pub fn predict(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len() + 1, self.d, "feature arity mismatch");
        self.predict_aug(&self.aug(x))
    }

    /// Number of observations so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// True once the model has seen enough data to be trusted by the
    /// scheduler (before that, callers fall back to profile means).
    pub fn warmed_up(&self) -> bool {
        self.n >= 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn learns_linear_function_exactly() {
        let mut m = OnlineLinReg::new(2, 1.0);
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let x = [rng.uniform(-5.0, 5.0), rng.uniform(-5.0, 5.0)];
            let y = 3.0 * x[0] - 2.0 * x[1] + 7.0;
            m.observe(&x, y);
        }
        // RLS with a finite prior is ridge-biased; 1e-3 is "exact" here.
        let pred = m.predict(&[1.0, 1.0]);
        assert!((pred - 8.0).abs() < 1e-3, "pred {pred}");
    }

    #[test]
    fn learns_under_noise() {
        let mut m = OnlineLinReg::new(1, 1.0);
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let x = rng.uniform(0.0, 10.0);
            let y = 0.5 * x + 2.0 + rng.normal() * 0.1;
            m.observe(&[x], y);
        }
        let pred = m.predict(&[4.0]);
        assert!((pred - 4.0).abs() < 0.1, "pred {pred}");
    }

    #[test]
    fn forgetting_tracks_drift() {
        let mut m = OnlineLinReg::new(1, 0.95);
        let mut rng = Rng::new(2);
        // regime 1: y = x
        for _ in 0..300 {
            let x = rng.uniform(0.0, 10.0);
            m.observe(&[x], x);
        }
        // regime 2: y = 3x (drifted workload)
        for _ in 0..300 {
            let x = rng.uniform(0.0, 10.0);
            m.observe(&[x], 3.0 * x);
        }
        let pred = m.predict(&[5.0]);
        assert!((pred - 15.0).abs() < 0.5, "pred {pred}");
    }

    #[test]
    fn warmup_threshold() {
        let mut m = OnlineLinReg::new(1, 1.0);
        assert!(!m.warmed_up());
        for i in 0..8 {
            m.observe(&[i as f64], i as f64);
        }
        assert!(m.warmed_up());
    }
}
