//! Exponentially-weighted moving average — the controller's smoother for
//! per-component load, service-rate and branch-frequency telemetry (§3.3.1
//! "Resource Reallocation" re-estimates α, γ, p from these).

/// EWMA with configurable smoothing factor `alpha` in (0, 1].
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1]");
        Ewma { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    /// Current estimate, or `default` if nothing observed yet.
    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_initializes() {
        let mut e = Ewma::new(0.2);
        assert_eq!(e.get(), None);
        e.observe(10.0);
        assert_eq!(e.get(), Some(10.0));
    }

    #[test]
    fn converges_to_constant_signal() {
        let mut e = Ewma::new(0.3);
        for _ in 0..100 {
            e.observe(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn tracks_level_shift() {
        let mut e = Ewma::new(0.5);
        for _ in 0..20 {
            e.observe(1.0);
        }
        for _ in 0..20 {
            e.observe(9.0);
        }
        assert!((e.get().unwrap() - 9.0).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_alpha() {
        Ewma::new(0.0);
    }
}
