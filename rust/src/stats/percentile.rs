//! Percentile helpers and a fixed-bucket log-scale histogram for latency
//! recording (SLO attainment, p50/p95/p99 reporting).

/// Exact percentile of a sample (interpolated, like numpy's 'linear').
/// `p` in [0, 100].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    let rank = (p / 100.0) * (sorted.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Log-scale histogram over (1us, ~1000s) with bounded memory; used where
/// storing every sample would be too expensive (DES with millions of
/// events).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// buckets[i] counts samples in [lo * GROWTH^i, lo * GROWTH^{i+1}).
    buckets: Vec<u64>,
    lo: f64,
    growth: f64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const N_BUCKETS: usize = 256;

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; N_BUCKETS],
            lo: 1e-6,
            growth: 1.09, // 256 buckets cover 1e-6 .. ~4e3 seconds
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(&self, x: f64) -> usize {
        if x <= self.lo {
            return 0;
        }
        let b = ((x / self.lo).ln() / self.growth.ln()) as usize;
        b.min(N_BUCKETS - 1)
    }

    pub fn observe(&mut self, x: f64) {
        let b = self.bucket_of(x);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile (bucket upper edge); error bounded by growth
    /// factor (~9%).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (self.lo * self.growth.powi(i as i32 + 1)).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert!((percentile(&v, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&v, 90.0) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_close_to_exact() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(0);
        let mut xs: Vec<f64> = (0..50_000).map(|_| rng.lognormal(-3.0, 1.0)).collect();
        for &x in &xs {
            h.observe(x);
        }
        xs.sort_by(f64::total_cmp);
        for q in [0.5, 0.9, 0.99] {
            let exact = percentile(&xs, q * 100.0);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.15, "q={q} exact={exact} approx={approx}");
        }
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = Histogram::new();
        for x in [0.1, 0.2, 0.3] {
            h.observe(x);
        }
        assert!((h.mean() - 0.2).abs() < 1e-12);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn histogram_quantile_monotone_property() {
        property("hist quantile monotone", 30, |g| {
            let n = g.usize(1, 500);
            let mut h = Histogram::new();
            for _ in 0..n {
                h.observe(g.f64(1e-6, 100.0));
            }
            let q1 = h.quantile(0.5);
            let q2 = h.quantile(0.9);
            let q3 = h.quantile(0.99);
            assert!(q1 <= q2 + 1e-12 && q2 <= q3 + 1e-12);
        });
    }
}
