//! Index-first analysis core: the compiler layer over [`PipelineGraph`].
//!
//! `spec::graph` accumulated ad-hoc traversals — `fork_groups()` rebuilt
//! as a `HashMap` on demand, `visit_rates`/`latency_edge_weights`
//! re-walking the edge list, validation re-deriving reachability — and
//! every downstream layer (LP construction, profiler walks, DES
//! dispatch, the live controller) paid for its own copy. This module
//! builds, **once per graph**, an [`AnalyzedGraph`] bundle of dense
//! `Vec`-indexed tables that all of them share:
//!
//! * cached [`Adjacency`] (out/in edge indices, declaration order),
//! * a topological order over the forward (non-back) edges,
//! * dominator and post-dominator trees of the DAG backbone,
//! * a fork-**region tree** ([`ForkRegion`]) replacing the on-demand
//!   `fork_groups()` HashMap with node-indexed regions,
//! * per-node join scales and visit rates, per-edge flow fractions.
//!
//! The numeric kernels (`visit_rates_with`, `edge_flows_from`,
//! `latency_edge_weights_from`) are the *same* fixed points the graph
//! methods used to own — `PipelineGraph::visit_rates()` et al. now
//! delegate here, so legacy callers and `AnalyzedGraph` consumers read
//! literally the same table and golden traces replay bit-identically.

use std::collections::HashMap;

use super::graph::{Adjacency, ForkGroup, JoinPolicy, NodeId, PipelineGraph};

/// One fork/join region resolved on the DAG backbone: the fork node, its
/// join, and the set of branch-interior nodes (the join itself is
/// excluded — it runs once, after the barrier). Regions form a tree:
/// `parent` points at the innermost enclosing region when forks nest.
#[derive(Clone, Debug)]
pub struct ForkRegion {
    pub fork: NodeId,
    pub join: NodeId,
    /// Node-indexed membership of the branch interiors (union over all
    /// branches; join excluded).
    pub members: Vec<bool>,
    /// Index (into [`AnalyzedGraph::regions`]) of the innermost region
    /// that contains this region's fork node, if any.
    pub parent: Option<usize>,
}

/// Dense per-graph analysis bundle, built once by
/// [`PipelineGraph::analyze`] and shared by every consumer that used to
/// re-derive its own traversal state:
///
/// * `alloc::flow` reads `join_scales` and the adjacency for its
///   capacity/conservation rows,
/// * the profiler's sampling walk indexes `fork_map` per hop,
/// * `sched::SlackPredictor` prices remaining work off the critical-path
///   edge weights,
/// * the DES and the live controller drive fork dispatch / join barriers
///   off `fork_map`,
/// * `spec::passes` rewrites consult the region tree, and
/// * `spec::export` overlays the tables onto DOT output.
///
/// All tables are indexed by `NodeId.0` (nodes) or edge-declaration
/// index (edges). Construction is best-effort on unvalidated graphs,
/// mirroring `fork_groups()`: forks whose join cannot be resolved are
/// simply absent from `fork_map` — `validate()` rejects such graphs with
/// a precise error.
#[derive(Clone, Debug)]
pub struct AnalyzedGraph {
    /// Out/in edge indices per node, edge-declaration order.
    pub adj: Adjacency,
    /// Topological order over forward (non-back) edges. On graphs whose
    /// forward edges contain a cycle (invalid; caught by `validate()`)
    /// the stranded nodes are appended in id order.
    pub topo: Vec<NodeId>,
    /// Immediate dominator per node on the forward-edge DAG from
    /// `source` (`None` for the source itself and for nodes not
    /// forward-reachable from it).
    pub idom: Vec<Option<NodeId>>,
    /// Immediate post-dominator per node (forward-edge DAG walked
    /// backwards from `sink`).
    pub ipdom: Vec<Option<NodeId>>,
    /// Dense fork index: `fork_map[n]` is the [`ForkGroup`] whose fork
    /// node is `n`, if any. Replaces `fork_groups()`'s on-demand
    /// `HashMap` in every hot path.
    pub fork_map: Vec<Option<ForkGroup>>,
    /// The fork-region tree (one entry per resolved fork, node order).
    pub regions: Vec<ForkRegion>,
    /// Region index owned by a fork node, if it is one.
    pub fork_region_of: Vec<Option<usize>>,
    /// Region index a join node reconverges, if it is one.
    pub join_region_of: Vec<Option<usize>>,
    /// Per-node inflow scale: 1/branches at joins, 1.0 elsewhere (see
    /// `PipelineGraph::join_scales`).
    pub join_scales: Vec<f64>,
    /// Expected visits per admitted request, per node.
    pub visit_rates: Vec<f64>,
    /// Flow fraction per edge (visit rate of `from` × γ × edge prob).
    pub edge_flows: Vec<f64>,
}

impl AnalyzedGraph {
    /// Build every index for `g`. O(V·E) worst case, run once per
    /// deploy/plan/simulation — never per request.
    pub fn new(g: &PipelineGraph) -> AnalyzedGraph {
        let n = g.nodes.len();
        let adj = Adjacency::new(g);
        let fork_map = fork_groups_dense(g, &adj);
        let join_scales = join_scales_from(g, &fork_map);
        let visit_rates = visit_rates_with(g, &join_scales);
        let edge_flows = edge_flows_from(g, &visit_rates);
        let topo = topo_order(g, &adj);
        let idom = dominator_tree(g, &adj, g.source, false);
        let ipdom = dominator_tree(g, &adj, g.sink, true);

        let mut regions: Vec<ForkRegion> = Vec::new();
        let mut fork_region_of = vec![None; n];
        let mut join_region_of = vec![None; n];
        for fg in fork_map.iter().flatten() {
            let mut members = vec![false; n];
            for &t in &fg.targets {
                let r = forward_reachable(g, &adj, t, Some(fg.join));
                for (i, &in_r) in r.iter().enumerate() {
                    if in_r && i != fg.join.0 {
                        members[i] = true;
                    }
                }
            }
            let idx = regions.len();
            fork_region_of[fg.fork.0] = Some(idx);
            join_region_of[fg.join.0] = Some(idx);
            regions.push(ForkRegion { fork: fg.fork, join: fg.join, members, parent: None });
        }
        // Parent links: the innermost (smallest) region whose interior
        // contains this region's fork node.
        for i in 0..regions.len() {
            let mut best: Option<usize> = None;
            for j in 0..regions.len() {
                if i == j || !regions[j].members[regions[i].fork.0] {
                    continue;
                }
                best = Some(match best {
                    None => j,
                    Some(b) => {
                        let cb = regions[b].members.iter().filter(|&&x| x).count();
                        let cj = regions[j].members.iter().filter(|&&x| x).count();
                        if cj < cb {
                            j
                        } else {
                            b
                        }
                    }
                });
            }
            regions[i].parent = best;
        }

        AnalyzedGraph {
            adj,
            topo,
            idom,
            ipdom,
            fork_map,
            regions,
            fork_region_of,
            join_region_of,
            join_scales,
            visit_rates,
            edge_flows,
        }
    }

    /// The fork group rooted at `id`, if `id` is a resolved fork node.
    pub fn fork_group(&self, id: NodeId) -> Option<&ForkGroup> {
        self.fork_map[id.0].as_ref()
    }

    /// Inflow scale of `id` (1/branches at a join, 1.0 elsewhere).
    pub fn join_scale(&self, id: NodeId) -> f64 {
        self.join_scales[id.0]
    }

    /// Critical-path latency weights over this graph's fork index (see
    /// `PipelineGraph::latency_edge_weights`).
    pub fn latency_edge_weights(
        &self,
        g: &PipelineGraph,
        node_cost: &HashMap<NodeId, f64>,
    ) -> Vec<f64> {
        latency_edge_weights_from(g, &self.fork_map, node_cost)
    }
}

// ---------------------------------------------------------------------------
// Shared traversal kernels. These are the former `PipelineGraph` private
// helpers and numeric methods, moved here verbatim so the delegating
// graph methods and the dense tables compute bit-identical values.
// ---------------------------------------------------------------------------

/// Nodes forward-reachable from `start` (inclusive), stopping at
/// `absorb` (the absorbing node is included but not expanded). Back
/// edges are never followed.
pub(crate) fn forward_reachable(
    g: &PipelineGraph,
    adj: &Adjacency,
    start: NodeId,
    absorb: Option<NodeId>,
) -> Vec<bool> {
    let mut reach = vec![false; g.nodes.len()];
    let mut stack = vec![start];
    reach[start.0] = true;
    while let Some(u) = stack.pop() {
        if Some(u) == absorb {
            continue;
        }
        for &ei in adj.out_edges(u) {
            let e = &g.edges[ei];
            if !e.back_edge && !reach[e.to.0] {
                reach[e.to.0] = true;
                stack.push(e.to);
            }
        }
    }
    reach
}

/// The join node a fork's branches reconverge at: the join-annotated
/// node forward-reachable from the most branches, nearest to the fork
/// on ties. `None` when no branch reaches any join.
pub(crate) fn resolve_join(
    g: &PipelineGraph,
    adj: &Adjacency,
    targets: &[NodeId],
) -> Option<NodeId> {
    let reach: Vec<Vec<bool>> =
        targets.iter().map(|&t| forward_reachable(g, adj, t, None)).collect();
    let mut best: Option<(usize, usize, NodeId)> = None; // (branches, -depth proxy, id)
    for n in &g.nodes {
        if n.join.is_none() {
            continue;
        }
        let hit = reach.iter().filter(|r| r[n.id.0]).count();
        if hit == 0 {
            continue;
        }
        // Depth proxy: min BFS depth from any branch target.
        let depth = min_depth(g, adj, targets, n.id);
        let cand = (hit, depth, n.id);
        best = Some(match best {
            None => cand,
            Some(b) => {
                if cand.0 > b.0 || (cand.0 == b.0 && cand.1 < b.1) {
                    cand
                } else {
                    b
                }
            }
        });
    }
    best.map(|(_, _, id)| id)
}

fn min_depth(g: &PipelineGraph, adj: &Adjacency, starts: &[NodeId], goal: NodeId) -> usize {
    use std::collections::VecDeque;
    let mut dist = vec![usize::MAX; g.nodes.len()];
    let mut q = VecDeque::new();
    for &s in starts {
        dist[s.0] = 0;
        q.push_back(s);
    }
    while let Some(u) = q.pop_front() {
        if u == goal {
            return dist[u.0];
        }
        for &ei in adj.out_edges(u) {
            let e = &g.edges[ei];
            if !e.back_edge && dist[e.to.0] == usize::MAX {
                dist[e.to.0] = dist[u.0] + 1;
                q.push_back(e.to);
            }
        }
    }
    usize::MAX
}

/// Resolve every fork node to its [`ForkGroup`], dense by fork node id.
/// Same best-effort semantics as the legacy `fork_groups()` HashMap:
/// forks whose join cannot be resolved are left `None`.
pub fn fork_groups_dense(g: &PipelineGraph, adj: &Adjacency) -> Vec<Option<ForkGroup>> {
    let mut groups: Vec<Option<ForkGroup>> = vec![None; g.nodes.len()];
    for n in &g.nodes {
        let edges: Vec<usize> = adj
            .out_edges(n.id)
            .iter()
            .copied()
            .filter(|&i| g.edges[i].is_fork())
            .collect();
        if edges.is_empty() {
            continue;
        }
        let targets: Vec<NodeId> = edges.iter().map(|&i| g.edges[i].to).collect();
        let Some(join) = resolve_join(g, adj, &targets) else { continue };
        let spec = g.node(join).join.expect("resolved join is annotated");
        groups[n.id.0] = Some(ForkGroup {
            fork: n.id,
            join,
            need: spec.need(targets.len()),
            targets,
            edges,
            policy: spec.policy,
            merge: spec.merge,
        });
    }
    groups
}

/// Per-node inflow scales from a dense fork index: 1/branches at each
/// resolved join, 1.0 everywhere else.
pub fn join_scales_from(g: &PipelineGraph, fork_map: &[Option<ForkGroup>]) -> Vec<f64> {
    let mut s = vec![1.0; g.nodes.len()];
    for fg in fork_map.iter().flatten() {
        s[fg.join.0] = 1.0 / fg.targets.len().max(1) as f64;
    }
    s
}

/// The visits fixed point v_j = [j==source] + Σ_i v_i γ_i w_{i,j} s_j
/// with per-node inflow scales `scale` (see
/// `PipelineGraph::visit_rates`). Edges are folded in declaration
/// order; converges for sub-stochastic loops.
pub fn visit_rates_with(g: &PipelineGraph, scale: &[f64]) -> Vec<f64> {
    let n = g.nodes.len();
    let mut v = vec![0.0f64; n];
    v[g.source.0] = 1.0;
    for _ in 0..10_000 {
        let mut nv = vec![0.0f64; n];
        nv[g.source.0] = 1.0;
        for e in &g.edges {
            let s = if e.back_edge { 1.0 } else { scale[e.to.0] };
            nv[e.to.0] += v[e.from.0] * g.node(e.from).gamma * e.prob() * s;
        }
        let diff: f64 = nv.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
        v = nv;
        if diff < 1e-12 {
            break;
        }
    }
    v
}

/// Per-edge flow fractions from a visit-rate table: visit rate of
/// `from` × γ × edge flow fraction, edge-declaration order. This is THE
/// flow table — `PipelineGraph::edge_flows()`, the LP's conservation
/// rows, and the DES all consume it (directly or via delegation).
pub fn edge_flows_from(g: &PipelineGraph, visit_rates: &[f64]) -> Vec<f64> {
    g.edges
        .iter()
        .map(|e| visit_rates[e.from.0] * g.node(e.from).gamma * e.prob())
        .collect()
}

/// Expected prior cost of one branch: visits fixed point from the
/// branch entry with the join absorbing, dotted with `node_cost`.
pub(crate) fn branch_cost(
    g: &PipelineGraph,
    entry: NodeId,
    join: NodeId,
    node_cost: &HashMap<NodeId, f64>,
) -> f64 {
    let n = g.nodes.len();
    let mut v = vec![0.0f64; n];
    v[entry.0] = 1.0;
    for _ in 0..10_000 {
        let mut nv = vec![0.0f64; n];
        nv[entry.0] = 1.0;
        for e in &g.edges {
            if e.from == join {
                continue; // absorb at the join
            }
            nv[e.to.0] += v[e.from.0] * g.node(e.from).gamma * e.prob();
        }
        let diff: f64 = nv.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
        v = nv;
        if diff < 1e-12 {
            break;
        }
    }
    v.iter()
        .enumerate()
        .filter(|&(i, _)| NodeId(i) != join)
        .map(|(i, &vi)| vi * node_cost.get(&NodeId(i)).copied().unwrap_or(0.0))
        .sum()
}

/// Critical-path latency weights from a dense fork index: `Route(p)`
/// edges keep p; within each fork group the critical branch (costliest
/// for `All`, k-th fastest for `FirstK(k)`) carries 1 and siblings 0
/// (see `PipelineGraph::latency_edge_weights`).
pub fn latency_edge_weights_from(
    g: &PipelineGraph,
    fork_map: &[Option<ForkGroup>],
    node_cost: &HashMap<NodeId, f64>,
) -> Vec<f64> {
    let mut w: Vec<f64> = g.edges.iter().map(|e| e.prob()).collect();
    for fg in fork_map.iter().flatten() {
        // Rank branches by prior path cost (entry → join).
        let mut costs: Vec<(usize, f64)> = fg
            .targets
            .iter()
            .enumerate()
            .map(|(bi, &t)| (bi, branch_cost(g, t, fg.join, node_cost)))
            .collect();
        costs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        let critical = match fg.policy {
            JoinPolicy::All => costs.last().map(|&(bi, _)| bi).unwrap_or(0),
            JoinPolicy::FirstK(k) => costs
                .get(k.saturating_sub(1).min(costs.len().saturating_sub(1)))
                .map(|&(bi, _)| bi)
                .unwrap_or(0),
        };
        for (bi, &ei) in fg.edges.iter().enumerate() {
            w[ei] = if bi == critical { 1.0 } else { 0.0 };
        }
    }
    w
}

/// Topological order over the forward (non-back) edges. Deterministic:
/// repeated id-order sweeps, placing every ready node per sweep. Nodes
/// stranded by a forward cycle (invalid graphs) are appended in id
/// order so the result always permutes all nodes.
fn topo_order(g: &PipelineGraph, adj: &Adjacency) -> Vec<NodeId> {
    let n = g.nodes.len();
    let mut indeg = vec![0usize; n];
    for e in &g.edges {
        if !e.back_edge {
            indeg[e.to.0] += 1;
        }
    }
    let mut placed = vec![false; n];
    let mut order = Vec::with_capacity(n);
    loop {
        let mut advanced = false;
        for i in 0..n {
            if !placed[i] && indeg[i] == 0 {
                placed[i] = true;
                order.push(NodeId(i));
                for &ei in adj.out_edges(NodeId(i)) {
                    let e = &g.edges[ei];
                    if !e.back_edge {
                        indeg[e.to.0] -= 1;
                    }
                }
                advanced = true;
            }
        }
        if !advanced {
            break;
        }
    }
    for i in 0..n {
        if !placed[i] {
            order.push(NodeId(i));
        }
    }
    order
}

/// Immediate-dominator tree on the forward-edge DAG. `reversed = false`
/// walks from `root` along edge direction (dominators from the source);
/// `reversed = true` walks against it (post-dominators from the sink).
/// Iterative set-intersection dataflow — graphs here are tiny (tens of
/// nodes), so the dense formulation beats the classic
/// Lengauer–Tarjan bookkeeping on clarity at no observable cost.
fn dominator_tree(
    g: &PipelineGraph,
    adj: &Adjacency,
    root: NodeId,
    reversed: bool,
) -> Vec<Option<NodeId>> {
    let n = g.nodes.len();
    let walk_preds = |v: usize| -> Vec<usize> {
        if reversed {
            adj.out_edges(NodeId(v))
                .iter()
                .filter(|&&ei| !g.edges[ei].back_edge)
                .map(|&ei| g.edges[ei].to.0)
                .collect()
        } else {
            adj.in_edges(NodeId(v))
                .iter()
                .filter(|&&ei| !g.edges[ei].back_edge)
                .map(|&ei| g.edges[ei].from.0)
                .collect()
        }
    };
    // Reachability from the root in the walk direction.
    let mut reach = vec![false; n];
    let mut stack = vec![root.0];
    reach[root.0] = true;
    while let Some(u) = stack.pop() {
        let nexts: Vec<usize> = if reversed {
            adj.in_edges(NodeId(u))
                .iter()
                .filter(|&&ei| !g.edges[ei].back_edge)
                .map(|&ei| g.edges[ei].from.0)
                .collect()
        } else {
            adj.out_edges(NodeId(u))
                .iter()
                .filter(|&&ei| !g.edges[ei].back_edge)
                .map(|&ei| g.edges[ei].to.0)
                .collect()
        };
        for v in nexts {
            if !reach[v] {
                reach[v] = true;
                stack.push(v);
            }
        }
    }
    // dom(root) = {root}; dom(v) = {v} ∪ ⋂_{p ∈ preds(v)} dom(p).
    let mut dom: Vec<Vec<bool>> = (0..n)
        .map(|v| {
            if v == root.0 {
                let mut d = vec![false; n];
                d[v] = true;
                d
            } else {
                vec![true; n]
            }
        })
        .collect();
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n {
            if v == root.0 || !reach[v] {
                continue;
            }
            let mut nd = vec![true; n];
            let mut any_pred = false;
            for p in walk_preds(v) {
                if !reach[p] {
                    continue;
                }
                any_pred = true;
                for i in 0..n {
                    nd[i] = nd[i] && dom[p][i];
                }
            }
            if !any_pred {
                nd = vec![false; n];
            }
            nd[v] = true;
            if nd != dom[v] {
                dom[v] = nd;
                changed = true;
            }
        }
    }
    // Strict dominators are totally ordered; the immediate one is the
    // strict dominator with the largest dominator set of its own.
    let mut idom = vec![None; n];
    for v in 0..n {
        if v == root.0 || !reach[v] {
            continue;
        }
        let mut best: Option<usize> = None;
        for d in 0..n {
            if d == v || !dom[v][d] {
                continue;
            }
            best = Some(match best {
                None => d,
                Some(b) => {
                    let cb = dom[b].iter().filter(|&&x| x).count();
                    let cd = dom[d].iter().filter(|&&x| x).count();
                    if cd > cb {
                        d
                    } else {
                        b
                    }
                }
            });
        }
        idom[v] = best.map(NodeId);
    }
    idom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    /// Every registered app, including the fork/join and `-seq` shapes.
    fn registry() -> Vec<PipelineGraph> {
        [
            "v-rag",
            "v-rag-sharded",
            "v-rag-cached",
            "c-rag",
            "s-rag",
            "a-rag",
            "hybrid-rag",
            "hybrid-rag-seq",
            "mq-rag",
            "mq-rag-seq",
        ]
        .iter()
        .map(|n| apps::by_name(n).unwrap())
        .collect()
    }

    #[test]
    fn analyzed_tables_match_the_legacy_graph_methods_bitwise() {
        for g in registry() {
            let az = g.analyze();
            assert_eq!(az.visit_rates, g.visit_rates(), "{} visit rates", g.name);
            assert_eq!(az.edge_flows, g.edge_flows(), "{} edge flows", g.name);
            assert_eq!(az.join_scales, g.join_scales(), "{} join scales", g.name);
            let legacy = g.fork_groups();
            let dense: Vec<&ForkGroup> = az.fork_map.iter().flatten().collect();
            assert_eq!(dense.len(), legacy.len(), "{} fork count", g.name);
            for fg in dense {
                let l = &legacy[&fg.fork];
                assert_eq!(fg.join, l.join, "{}", g.name);
                assert_eq!(fg.targets, l.targets, "{}", g.name);
                assert_eq!(fg.edges, l.edges, "{}", g.name);
                assert_eq!(fg.need, l.need, "{}", g.name);
                assert_eq!(fg.policy, l.policy, "{}", g.name);
            }
        }
    }

    #[test]
    fn topo_order_is_topological_on_the_forward_edges() {
        for g in registry() {
            let az = g.analyze();
            let mut pos = vec![0usize; g.nodes.len()];
            assert_eq!(az.topo.len(), g.nodes.len(), "{} permutes all nodes", g.name);
            for (i, &id) in az.topo.iter().enumerate() {
                pos[id.0] = i;
            }
            for e in g.edges.iter().filter(|e| !e.back_edge) {
                assert!(
                    pos[e.from.0] < pos[e.to.0],
                    "{}: edge {:?}->{:?} violates topo order",
                    g.name,
                    e.from,
                    e.to
                );
            }
        }
    }

    #[test]
    fn dominators_and_post_dominators_on_hybrid_rag() {
        let g = apps::hybrid_rag();
        let az = g.analyze();
        let retr = g.node_by_name("retriever").unwrap().id;
        let web = g.node_by_name("websearch").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        // The fork dominates both branches and the join.
        assert_eq!(az.idom[retr.0], Some(g.source));
        assert_eq!(az.idom[web.0], Some(g.source));
        assert_eq!(az.idom[gen.0], Some(g.source), "neither branch dominates the join");
        assert_eq!(az.idom[g.source.0], None);
        // The join post-dominates both branches and the fork.
        assert_eq!(az.ipdom[retr.0], Some(gen));
        assert_eq!(az.ipdom[web.0], Some(gen));
        assert_eq!(az.ipdom[g.source.0], Some(gen));
        assert_eq!(az.ipdom[gen.0], Some(g.sink));
        assert_eq!(az.ipdom[g.sink.0], None);
    }

    #[test]
    fn dominators_are_a_chain_on_linear_pipelines() {
        let g = apps::vanilla_rag();
        let az = g.analyze();
        let retr = g.node_by_name("retriever").unwrap().id;
        let gen = g.node_by_name("generator").unwrap().id;
        assert_eq!(az.idom[retr.0], Some(g.source));
        assert_eq!(az.idom[gen.0], Some(retr));
        assert_eq!(az.idom[g.sink.0], Some(gen));
        assert_eq!(az.ipdom[retr.0], Some(gen));
        assert_eq!(az.ipdom[g.source.0], Some(retr));
    }

    #[test]
    fn fork_region_tree_captures_branch_interiors() {
        let g = apps::hybrid_rag();
        let az = g.analyze();
        assert_eq!(az.regions.len(), 1);
        let r = &az.regions[0];
        assert_eq!(r.fork, g.source);
        assert_eq!(r.join, g.node_by_name("generator").unwrap().id);
        assert_eq!(r.parent, None);
        let members: Vec<&str> = g
            .nodes
            .iter()
            .filter(|n| r.members[n.id.0])
            .map(|n| n.name.as_str())
            .collect();
        assert_eq!(members, vec!["retriever", "websearch"]);
        assert_eq!(az.fork_region_of[g.source.0], Some(0));
        assert_eq!(az.join_region_of[r.join.0], Some(0));
        // Linear pipelines have no regions at all.
        let lin = apps::vanilla_rag().analyze();
        assert!(lin.regions.is_empty());
        assert!(lin.fork_region_of.iter().all(|r| r.is_none()));
    }

    #[test]
    fn analyzed_latency_weights_match_the_legacy_method() {
        let g = apps::hybrid_rag();
        let az = g.analyze();
        let mut cost: HashMap<NodeId, f64> = HashMap::new();
        cost.insert(g.node_by_name("retriever").unwrap().id, 0.1);
        cost.insert(g.node_by_name("websearch").unwrap().id, 0.15);
        cost.insert(g.node_by_name("generator").unwrap().id, 0.1);
        assert_eq!(az.latency_edge_weights(&g, &cost), g.latency_edge_weights(&cost));
    }

    #[test]
    fn fork_group_and_join_scale_accessors_index_densely() {
        let g = apps::multiquery_rag(3);
        let az = g.analyze();
        let fg = az.fork_group(g.source).expect("source forks");
        assert_eq!(fg.targets.len(), 3);
        assert_eq!(az.join_scale(fg.join), 1.0 / 3.0);
        assert_eq!(az.join_scale(g.source), 1.0);
        assert!(az.fork_group(fg.join).is_none());
    }
}
