//! The RAG **specification layer** (§3.1): pipelines as component graphs
//! with conditional branches, recursion, parallel (fork/join) dataflow,
//! request amplification, and declarative constraints (stateful routing,
//! resource demands, base instances).
//!
//! Edges are typed ([`EdgeKind`]): probabilistic `Route(p)` edges pick
//! exactly one successor per visit, while `Fork` edges fan the request
//! out to every successor as sibling subtasks that reconverge at a
//! [`JoinSpec`]-annotated barrier (`All` or racing `FirstK(k)`, with a
//! [`MergePolicy`] for the branch results).
//!
//! The paper captures this graph from idiomatic Python via AST analysis;
//! here the same machine-readable representation is produced by an
//! imperative [`builder::PipelineBuilder`] (the capture substitute), and
//! [`apps`] provides the four reference workflows of Table 1.

pub mod apps;
pub mod builder;
pub mod graph;

pub use builder::PipelineBuilder;
pub use graph::{
    Adjacency, ComponentKind, DegradeKnob, EdgeKind, EdgeSpec, ForkGroup, JoinPolicy, JoinSpec,
    MergePolicy, NodeId, NodeSpec, PipelineGraph, ResourceKind, ValidationError,
};
