//! The RAG **specification layer** (§3.1): pipelines as component graphs
//! with conditional branches, recursion, parallel (fork/join) dataflow,
//! request amplification, and declarative constraints (stateful routing,
//! resource demands, base instances).
//!
//! Edges are typed ([`EdgeKind`]): probabilistic `Route(p)` edges pick
//! exactly one successor per visit, while `Fork` edges fan the request
//! out to every successor as sibling subtasks that reconverge at a
//! [`JoinSpec`]-annotated barrier (`All` or racing `FirstK(k)`, with a
//! [`MergePolicy`] for the branch results).
//!
//! The paper captures this graph from idiomatic Python via AST analysis;
//! here the same machine-readable representation is produced by an
//! imperative [`builder::PipelineBuilder`] (the capture substitute), and
//! [`apps`] provides the four reference workflows of Table 1.

//! The spec layer is structured as a small **compiler**:
//! [`analysis::AnalyzedGraph`] builds dense indices (adjacency, topo
//! order, dominators, fork regions, visit rates, edge flows) once per
//! graph for every downstream consumer; [`passes`] hosts the opt-in
//! rewrite pipeline (speculative prefetch, stage fusion, fork
//! serialization — default OFF); [`export`] renders graphs to Graphviz
//! DOT with allocation/latency overlays.

pub mod analysis;
pub mod apps;
pub mod builder;
pub mod export;
pub mod graph;
pub mod passes;

pub use analysis::{AnalyzedGraph, ForkRegion};
pub use builder::PipelineBuilder;
pub use export::{to_dot, to_dot_with, DotOverlay};
pub use graph::{
    Adjacency, ComponentKind, DegradeKnob, EdgeKind, EdgeSpec, ForkGroup, JoinPolicy, JoinSpec,
    MergePolicy, NodeId, NodeSpec, PipelineGraph, ResourceKind, ValidationError,
};
pub use passes::{Pass, PassPipeline, Sequentialize, SpeculativePrefetch, StageFusion};
