//! The RAG **specification layer** (§3.1): pipelines as component graphs
//! with conditional branches, recursion, request amplification, and
//! declarative constraints (stateful routing, resource demands, base
//! instances).
//!
//! The paper captures this graph from idiomatic Python via AST analysis;
//! here the same machine-readable representation is produced by an
//! imperative [`builder::PipelineBuilder`] (the capture substitute), and
//! [`apps`] provides the four reference workflows of Table 1.

pub mod apps;
pub mod builder;
pub mod graph;

pub use builder::PipelineBuilder;
pub use graph::{
    ComponentKind, DegradeKnob, EdgeSpec, NodeId, NodeSpec, PipelineGraph, ResourceKind,
    ValidationError,
};
