//! Imperative pipeline builder — the capture substitute for the paper's
//! `with harmonia.capture():` AST analysis. Developers compose components
//! and control flow in plain Rust; the builder emits the same
//! machine-readable [`PipelineGraph`] the Python capture would.
//!
//! ```no_run
//! use harmonia::spec::{PipelineBuilder, ComponentKind, ResourceKind};
//! let mut b = PipelineBuilder::new("my-rag");
//! let retr = b.component("retriever", ComponentKind::Retriever)
//!     .resources(&[(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)])
//!     .base_instances(1)
//!     .add();
//! let gen = b.component("generator", ComponentKind::Generator)
//!     .resources(&[(ResourceKind::Gpu, 1.0)])
//!     .stateful(true)
//!     .add();
//! b.edge_from_source(retr, 1.0);
//! b.edge(retr, gen, 1.0);
//! b.edge_to_sink(gen, 1.0);
//! let graph = b.build().unwrap();
//! ```

use super::graph::{
    ComponentKind, DegradeKnob, EdgeKind, EdgeSpec, JoinSpec, NodeId, NodeSpec, PipelineGraph,
    ResourceKind, ValidationError,
};

/// Fluent per-component configuration (the `@harmonia.make(...)` decorator
/// arguments of Fig. 7).
pub struct ComponentBuilder<'a> {
    b: &'a mut PipelineBuilder,
    spec: NodeSpec,
}

impl<'a> ComponentBuilder<'a> {
    /// Mark as stateful: recursive invocations route to the same instance.
    pub fn stateful(mut self, yes: bool) -> Self {
        self.spec.stateful = yes;
        self
    }

    /// Minimum warm instances (cold-start protection).
    pub fn base_instances(mut self, n: usize) -> Self {
        self.spec.base_instances = n;
        self
    }

    /// Partition the component's data across `n` shards searched
    /// scatter-gather style (retrieval). Each shard's replica pool is
    /// sized independently by the allocator; per-instance `resources`
    /// describe ONE shard replica (holding ~1/n of the data).
    pub fn shards(mut self, n: usize) -> Self {
        self.spec.shards = n;
        self
    }

    /// Expected request-cache hit rate (retrieval memoization); the DES
    /// and the profiler shrink this fraction of visits to the cache-hit
    /// cost (`profile::models::CACHE_HIT_COST_FRAC`). Derive from the
    /// workload skew with `profile::models::zipf_hit_rate`.
    pub fn cache_hit_rate(mut self, h: f64) -> Self {
        self.spec.cache_hit_rate = h;
        self
    }

    /// Run the component's index scan scalar-quantized
    /// (`retrieval::Quantization::SQ8`): u8 codes + exact rescoring in
    /// place of the f32 scan. The DES and the profiler shrink its
    /// service time by `profile::models::quantized_service_factor`;
    /// the default `false` is an exact identity.
    pub fn quantized(mut self, yes: bool) -> Self {
        self.spec.quantized = yes;
        self
    }

    /// Declare which overload-degradation knob this component exposes
    /// (default: [`DegradeKnob::None`], never degraded). Acted on only
    /// when the control plane's `sched::DegradePolicy` is enabled.
    pub fn degrade(mut self, knob: DegradeKnob) -> Self {
        self.spec.degrade = knob;
        self
    }

    /// Mark this component as a **join**: the barrier where the branches
    /// of an upstream [`PipelineBuilder::fork`] reconverge. The component
    /// runs once per request, after the barrier releases, on the merged
    /// branch state (see [`JoinSpec`]).
    pub fn join(mut self, spec: JoinSpec) -> Self {
        self.spec.join = Some(spec);
        self
    }

    /// Per-instance resource demand.
    pub fn resources(mut self, r: &[(ResourceKind, f64)]) -> Self {
        self.spec.resources = r.to_vec();
        self
    }

    /// Override throughput coefficients α_{i,k} (otherwise profiled).
    pub fn alpha(mut self, a: &[(ResourceKind, f64)]) -> Self {
        self.spec.alpha = a.to_vec();
        self
    }

    /// Request amplification factor γ_i.
    pub fn gamma(mut self, g: f64) -> Self {
        self.spec.gamma = g;
        self
    }

    /// Whether output may stream to the successor (managed Streaming
    /// Object, §3.1).
    pub fn streamable(mut self, yes: bool) -> Self {
        self.spec.streamable = yes;
        self
    }

    /// Finish and register the component.
    pub fn add(self) -> NodeId {
        let id = self.spec.id;
        self.b.nodes.push(self.spec);
        id
    }
}

/// Builder for a [`PipelineGraph`]. Source and sink nodes are implicit.
pub struct PipelineBuilder {
    name: String,
    pub(crate) nodes: Vec<NodeSpec>,
    edges: Vec<EdgeSpec>,
    source: NodeId,
    sink: NodeId,
}

impl PipelineBuilder {
    pub fn new(name: &str) -> Self {
        let mk = |id: usize, name: &str, kind: ComponentKind| NodeSpec {
            id: NodeId(id),
            name: name.into(),
            kind,
            stateful: false,
            base_instances: 0,
            shards: 1,
            cache_hit_rate: 0.0,
            quantized: false,
            degrade: DegradeKnob::None,
            join: None,
            resources: vec![],
            alpha: vec![],
            gamma: 1.0,
            streamable: false,
        };
        PipelineBuilder {
            name: name.into(),
            nodes: vec![mk(0, "source", ComponentKind::Source), mk(1, "sink", ComponentKind::Sink)],
            edges: Vec::new(),
            source: NodeId(0),
            sink: NodeId(1),
        }
    }

    pub fn source(&self) -> NodeId {
        self.source
    }

    pub fn sink(&self) -> NodeId {
        self.sink
    }

    /// Begin a component definition (defaults: 1 base instance, γ=1,
    /// resource demand 1 GPU for GPU-bound kinds / 1 CPU otherwise,
    /// α left empty for the profiler to fill).
    pub fn component(&mut self, name: &str, kind: ComponentKind) -> ComponentBuilder<'_> {
        let id = NodeId(self.nodes.len());
        let default_res = if kind.gpu_bound() {
            vec![(ResourceKind::Gpu, 1.0)]
        } else {
            vec![(ResourceKind::Cpu, 1.0)]
        };
        let spec = NodeSpec {
            id,
            name: name.into(),
            kind,
            stateful: false,
            base_instances: 1,
            shards: 1,
            cache_hit_rate: 0.0,
            quantized: false,
            degrade: DegradeKnob::None,
            join: None,
            resources: default_res,
            alpha: vec![],
            gamma: 1.0,
            streamable: false,
        };
        ComponentBuilder { b: self, spec }
    }

    /// Add a forward edge with routing probability `p`.
    pub fn edge(&mut self, from: NodeId, to: NodeId, p: f64) -> &mut Self {
        self.edges.push(EdgeSpec { from, to, kind: EdgeKind::Route(p), back_edge: false });
        self
    }

    pub fn edge_from_source(&mut self, to: NodeId, p: f64) -> &mut Self {
        self.edge(self.source, to, p)
    }

    pub fn edge_to_sink(&mut self, from: NodeId, p: f64) -> &mut Self {
        self.edge(from, self.sink, p)
    }

    /// Conditional fan-out from `from`: each (target, probability).
    pub fn branch(&mut self, from: NodeId, arms: &[(NodeId, f64)]) -> &mut Self {
        for &(to, p) in arms {
            self.edge(from, to, p);
        }
        self
    }

    /// Parallel fan-out from `from`: every target runs concurrently as a
    /// sibling subtask ([`EdgeKind::Fork`]; full flow per branch). The
    /// branches must reconverge at a downstream component marked with
    /// [`ComponentBuilder::join`] — validation enforces balance.
    pub fn fork(&mut self, from: NodeId, targets: &[NodeId]) -> &mut Self {
        for &to in targets {
            self.edges.push(EdgeSpec { from, to, kind: EdgeKind::Fork, back_edge: false });
        }
        self
    }

    /// Recursion: a back edge re-entering an upstream component with
    /// probability `p` (e.g. Self-RAG's rewrite→retrieve loop).
    pub fn recurse(&mut self, from: NodeId, to: NodeId, p: f64) -> &mut Self {
        self.edges.push(EdgeSpec { from, to, kind: EdgeKind::Route(p), back_edge: true });
        self
    }

    /// Validate and produce the graph.
    pub fn build(self) -> Result<PipelineGraph, ValidationError> {
        let g = self.build_unvalidated();
        g.validate()?;
        Ok(g)
    }

    /// Produce the graph without validation (tests construct broken graphs).
    pub fn build_unvalidated(self) -> PipelineGraph {
        PipelineGraph {
            name: self.name,
            nodes: self.nodes,
            edges: self.edges,
            source: self.source,
            sink: self.sink,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_valid_linear_pipeline() {
        let mut b = PipelineBuilder::new("t");
        let r = b.component("r", ComponentKind::Retriever).add();
        let g = b.component("g", ComponentKind::Generator).add();
        b.edge_from_source(r, 1.0);
        b.edge(r, g, 1.0);
        b.edge_to_sink(g, 1.0);
        let graph = b.build().unwrap();
        assert_eq!(graph.work_nodes().count(), 2);
        assert_eq!(graph.name, "t");
    }

    #[test]
    fn defaults_follow_component_kind() {
        let mut b = PipelineBuilder::new("t");
        let r = b.component("r", ComponentKind::Retriever).add();
        let g = b.component("g", ComponentKind::Generator).add();
        b.edge_from_source(r, 1.0);
        b.edge(r, g, 1.0);
        b.edge_to_sink(g, 1.0);
        let graph = b.build().unwrap();
        assert!(graph.node(r).demand_for(ResourceKind::Cpu) > 0.0);
        assert_eq!(graph.node(r).demand_for(ResourceKind::Gpu), 0.0);
        assert!(graph.node(g).demand_for(ResourceKind::Gpu) > 0.0);
    }

    #[test]
    fn fork_and_join_build_a_valid_parallel_pipeline() {
        let mut b = PipelineBuilder::new("t");
        let r = b.component("r", ComponentKind::Retriever).add();
        let w = b.component("w", ComponentKind::WebSearch).add();
        let g = b
            .component("g", ComponentKind::Generator)
            .join(JoinSpec::all())
            .add();
        b.fork(b.source(), &[r, w]);
        b.edge(r, g, 1.0);
        b.edge(w, g, 1.0);
        b.edge_to_sink(g, 1.0);
        let graph = b.build().unwrap();
        assert!(graph.has_forks());
        assert_eq!(graph.edges.iter().filter(|e| e.is_fork()).count(), 2);
        assert_eq!(graph.node(g).join, Some(JoinSpec::all()));
        let groups = graph.fork_groups();
        assert_eq!(groups[&graph.source].join, g);
    }

    #[test]
    fn constraints_are_recorded() {
        let mut b = PipelineBuilder::new("t");
        let g = b
            .component("g", ComponentKind::Generator)
            .stateful(true)
            .base_instances(3)
            .shards(2)
            .cache_hit_rate(0.4)
            .quantized(true)
            .degrade(DegradeKnob::CapIterations)
            .gamma(1.5)
            .streamable(true)
            .add();
        b.edge_from_source(g, 1.0);
        b.edge_to_sink(g, 1.0);
        let graph = b.build().unwrap();
        let n = graph.node(g);
        assert!(n.stateful);
        assert_eq!(n.base_instances, 3);
        assert_eq!(n.shards, 2);
        assert_eq!(n.cache_hit_rate, 0.4);
        assert!(n.quantized);
        assert_eq!(n.degrade, DegradeKnob::CapIterations);
        assert_eq!(n.gamma, 1.5);
        assert!(n.streamable);
    }
}
