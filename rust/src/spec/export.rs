//! Graphviz DOT export — the spec compiler's inspection backend.
//!
//! [`to_dot`] renders the pipeline structure alone; [`to_dot_with`]
//! overlays runtime data per node (LP instance counts, modeled and
//! measured latencies) via a [`DotOverlay`]. Emission is fully
//! deterministic — nodes in id order, edges in declaration order, fixed
//! label-line order — so the output is snapshot-testable (three golden
//! snapshots live below) and diffable across PRs: `make graph-dot`
//! renders every registered app to `target/dot/`.
//!
//! Visual conventions: source/sink are ellipses, join barriers are
//! double octagons, everything else a box; fork edges are bold, back
//! (recursion) edges dashed, and probabilistic routes carry `p=…`
//! labels.

use super::graph::{
    ComponentKind, DegradeKnob, JoinPolicy, MergePolicy, NodeSpec, PipelineGraph, ResourceKind,
};

/// Optional per-node runtime annotations for [`to_dot_with`], each
/// indexed by `NodeId.0`. Short vectors (or `None` slots) simply omit
/// the line — the empty overlay renders pure structure.
#[derive(Clone, Debug, Default)]
pub struct DotOverlay {
    /// LP allocation: instances assigned to the node.
    pub instances: Vec<Option<usize>>,
    /// Modeled mean service time (ms) from the analytical model.
    pub modeled_ms: Vec<Option<f64>>,
    /// Measured mean service time (ms) from profiling or live telemetry.
    pub measured_ms: Vec<Option<f64>>,
}

impl DotOverlay {
    /// No annotations — structure only.
    pub fn empty() -> DotOverlay {
        DotOverlay::default()
    }
}

/// Format a resource quantity: integers without a decimal point
/// (`cpu=8`), everything else with one digit (`ram=0.5`).
fn qty(v: f64) -> String {
    if (v - v.round()).abs() < 1e-9 {
        format!("{}", v.round() as i64)
    } else {
        format!("{v:.1}")
    }
}

fn res_label(k: ResourceKind) -> &'static str {
    match k {
        ResourceKind::Cpu => "cpu",
        ResourceKind::Gpu => "gpu",
        ResourceKind::Ram => "ram",
    }
}

fn degrade_label(d: DegradeKnob) -> &'static str {
    match d {
        DegradeKnob::None => "",
        DegradeKnob::ShrinkTopK => "shrink-topk",
        DegradeKnob::SkipHop => "skip-hop",
        DegradeKnob::CapIterations => "cap-iters",
    }
}

fn node_label(n: &NodeSpec, overlay: &DotOverlay) -> String {
    let endpoint = matches!(n.kind, ComponentKind::Source | ComponentKind::Sink);
    let mut parts = vec![n.name.clone()];
    if !endpoint {
        parts.push(format!("[{}]", n.kind.name()));
    }
    if !n.resources.is_empty() {
        let res: Vec<String> =
            n.resources.iter().map(|&(k, v)| format!("{}={}", res_label(k), qty(v))).collect();
        parts.push(res.join(" "));
    }
    if n.shards > 1 {
        parts.push(format!("shards={}", n.shards));
    }
    if n.cache_hit_rate > 0.0 {
        parts.push(format!("cache={:.2}", n.cache_hit_rate));
    }
    if n.quantized {
        parts.push("sq8".to_string());
    }
    if n.gamma != 1.0 {
        parts.push(format!("gamma={}", n.gamma));
    }
    if n.stateful {
        parts.push("stateful".to_string());
    }
    if n.degrade != DegradeKnob::None {
        parts.push(format!("degrade={}", degrade_label(n.degrade)));
    }
    if let Some(j) = n.join {
        let policy = match j.policy {
            JoinPolicy::All => "all".to_string(),
            JoinPolicy::FirstK(k) => format!("first{k}"),
        };
        let merge = match j.merge {
            MergePolicy::Union => "union",
            MergePolicy::First => "first",
        };
        parts.push(format!("join={policy}/{merge}"));
    }
    if n.streamable {
        parts.push("stream".to_string());
    }
    let id = n.id.0;
    if let Some(inst) = overlay.instances.get(id).copied().flatten() {
        parts.push(format!("inst={inst}"));
    }
    if let Some(ms) = overlay.modeled_ms.get(id).copied().flatten() {
        parts.push(format!("model={ms:.1}ms"));
    }
    if let Some(ms) = overlay.measured_ms.get(id).copied().flatten() {
        parts.push(format!("meas={ms:.1}ms"));
    }
    parts.join("\\n")
}

/// Render the pipeline structure as Graphviz DOT (no overlay).
pub fn to_dot(g: &PipelineGraph) -> String {
    to_dot_with(g, &DotOverlay::empty())
}

/// Render the pipeline as Graphviz DOT with per-node runtime
/// annotations (allocations, modeled/measured latencies) overlaid on
/// the labels.
pub fn to_dot_with(g: &PipelineGraph, overlay: &DotOverlay) -> String {
    let mut out = String::new();
    out.push_str(&format!("digraph \"{}\" {{\n", g.name));
    out.push_str("  rankdir=LR;\n");
    out.push_str("  node [shape=box, fontname=\"Helvetica\"];\n");
    for n in &g.nodes {
        let shape = if matches!(n.kind, ComponentKind::Source | ComponentKind::Sink) {
            "ellipse"
        } else if n.join.is_some() {
            "doubleoctagon"
        } else {
            "box"
        };
        out.push_str(&format!(
            "  n{} [label=\"{}\", shape={}];\n",
            n.id.0,
            node_label(n, overlay),
            shape
        ));
    }
    for e in &g.edges {
        let attrs = if e.is_fork() {
            " [style=bold, label=\"fork\"]".to_string()
        } else if e.back_edge {
            if e.prob() != 1.0 {
                format!(" [style=dashed, label=\"p={:.2}\"]", e.prob())
            } else {
                " [style=dashed]".to_string()
            }
        } else if e.prob() != 1.0 {
            format!(" [label=\"p={:.2}\"]", e.prob())
        } else {
            String::new()
        };
        out.push_str(&format!("  n{} -> n{}{};\n", e.from.0, e.to.0, attrs));
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    // Golden snapshots: emission is deterministic, so these pin the DOT
    // backend byte-for-byte for a linear, a forked, and a conditional
    // app. If a legitimate format change lands, update the goldens in
    // the same PR.

    #[test]
    fn golden_dot_vanilla_rag() {
        let want = r#"digraph "v-rag" {
  rankdir=LR;
  node [shape=box, fontname="Helvetica"];
  n0 [label="source", shape=ellipse];
  n1 [label="sink", shape=ellipse];
  n2 [label="retriever\n[retriever]\ncpu=8 ram=112\ndegrade=shrink-topk\nstream", shape=box];
  n3 [label="generator\n[generator]\ngpu=1\nstream", shape=box];
  n0 -> n2;
  n2 -> n3;
  n3 -> n1;
}
"#;
        assert_eq!(to_dot(&apps::vanilla_rag()), want);
    }

    #[test]
    fn golden_dot_hybrid_rag() {
        let want = r#"digraph "hybrid-rag" {
  rankdir=LR;
  node [shape=box, fontname="Helvetica"];
  n0 [label="source", shape=ellipse];
  n1 [label="sink", shape=ellipse];
  n2 [label="retriever\n[retriever]\ncpu=8 ram=112\ndegrade=shrink-topk", shape=box];
  n3 [label="websearch\n[websearch]\ncpu=1", shape=box];
  n4 [label="generator\n[generator]\ngpu=1\njoin=all/union\nstream", shape=doubleoctagon];
  n0 -> n2 [style=bold, label="fork"];
  n0 -> n3 [style=bold, label="fork"];
  n2 -> n4;
  n3 -> n4;
  n4 -> n1;
}
"#;
        assert_eq!(to_dot(&apps::hybrid_rag()), want);
    }

    #[test]
    fn golden_dot_corrective_rag() {
        let want = r#"digraph "c-rag" {
  rankdir=LR;
  node [shape=box, fontname="Helvetica"];
  n0 [label="source", shape=ellipse];
  n1 [label="sink", shape=ellipse];
  n2 [label="retriever\n[retriever]\ncpu=8 ram=112\ndegrade=shrink-topk\nstream", shape=box];
  n3 [label="grader\n[grader]\ngpu=1\nstateful\ndegrade=skip-hop", shape=box];
  n4 [label="rewriter\n[rewriter]\ngpu=1", shape=box];
  n5 [label="websearch\n[websearch]\ncpu=1", shape=box];
  n6 [label="generator\n[generator]\ngpu=1\nstream", shape=box];
  n0 -> n2;
  n2 -> n3;
  n3 -> n6 [label="p=0.70"];
  n3 -> n4 [label="p=0.30"];
  n4 -> n5;
  n5 -> n6;
  n6 -> n1;
}
"#;
        assert_eq!(to_dot(&apps::corrective_rag()), want);
    }

    #[test]
    fn overlay_lines_append_in_fixed_order() {
        let g = apps::vanilla_rag();
        let mut ov = DotOverlay::empty();
        ov.instances = vec![None, None, Some(3), Some(2)];
        ov.modeled_ms = vec![None, None, Some(12.34), None];
        ov.measured_ms = vec![None, None, None, Some(150.0)];
        let dot = to_dot_with(&g, &ov);
        assert!(dot.contains("degrade=shrink-topk\\nstream\\ninst=3\\nmodel=12.3ms"));
        assert!(dot.contains("gpu=1\\nstream\\ninst=2\\nmeas=150.0ms"));
    }

    #[test]
    fn recursion_renders_dashed_back_edges() {
        let dot = to_dot(&apps::self_rag());
        // s-rag: rewriter loops back to the retriever with p=1.
        assert!(dot.contains("[style=dashed]"), "{dot}");
        // critic's accept branch carries its probability.
        assert!(dot.contains("[label=\"p=0.65\"]"), "{dot}");
    }

    #[test]
    fn every_registered_app_renders_and_mentions_all_nodes() {
        for name in [
            "v-rag",
            "v-rag-sharded",
            "v-rag-cached",
            "c-rag",
            "s-rag",
            "a-rag",
            "hybrid-rag",
            "hybrid-rag-seq",
            "mq-rag",
            "mq-rag-seq",
        ] {
            let g = apps::by_name(name).unwrap();
            let dot = to_dot(&g);
            for n in &g.nodes {
                assert!(dot.contains(&format!("n{} [label=\"{}", n.id.0, n.name)), "{name}/{}", n.name);
            }
            assert_eq!(dot.matches(" -> ").count(), g.edges.len(), "{name}");
        }
    }
}
