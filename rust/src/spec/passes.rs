//! Opt-in rewrite passes over the spec graph — the optimization half of
//! the spec compiler.
//!
//! A [`Pass`] consumes a [`PipelineGraph`] and either returns a
//! rewritten graph or `None` when it finds nothing to rewrite. Passes
//! are **default OFF**: the empty [`PassPipeline`] is the identity, so
//! every golden trace in the repo replays bit-identically unless a
//! caller explicitly opts in (the RAGO-style schedule search the paper
//! motivates, made mechanical).
//!
//! Three passes ship today:
//!
//! * [`SpeculativePrefetch`] — turns a *serial* chain of retrieval-class
//!   stages into a fork/join: all retrievals launch the moment the
//!   predecessor commits, and the consumer becomes the barrier. With
//!   the default [`JoinSpec::all`] every branch's context is fused;
//!   passing [`JoinSpec::first_k`] instead races the branches and
//!   cancels the losers through the existing FirstK machinery in the
//!   DES and the live controller.
//! * [`StageFusion`] — merges co-located cheap adjacent stages (rewrite
//!   → retrieve and similar) into one node, eliminating a queue/dispatch
//!   hop; the fused stage re-profiles as a `Custom` component.
//! * [`Sequentialize`] — the inverse of prefetch: mechanically derives
//!   the `*_sequential` control apps from their forked originals, so the
//!   hand-written `hybrid-rag-seq` / `mq-rag-seq` baselines are now
//!   *generated* (and pinned bit-identical to the retired hand-written
//!   constructions in `spec::apps` tests).

use super::analysis::{fork_groups_dense, forward_reachable};
use super::graph::{
    ComponentKind, DegradeKnob, EdgeKind, EdgeSpec, JoinSpec, NodeId, NodeSpec, PipelineGraph,
};

/// One graph-to-graph rewrite. Implementations must be *structural*:
/// they may add/remove/retarget nodes and edges but must preserve the
/// pipeline's admitted-request semantics (visit rates of surviving
/// stages, flow into the sink). `apply` returns `None` when the pass
/// does not apply to `g` — callers treat that as "no change", never as
/// an error.
pub trait Pass {
    /// Stable pass name, reported by [`PassPipeline::run`].
    fn name(&self) -> &'static str;
    /// Rewrite `g`, or `None` when nothing matched. Returned graphs are
    /// structurally valid for every shipped pass; callers that compose
    /// third-party passes should re-`validate()`.
    fn apply(&self, g: &PipelineGraph) -> Option<PipelineGraph>;
}

/// An ordered pass list. The default pipeline is **empty** — running it
/// returns the input unchanged, which is what keeps golden traces
/// bit-identical with the compiler layer in place.
#[derive(Default)]
pub struct PassPipeline {
    passes: Vec<Box<dyn Pass>>,
}

impl PassPipeline {
    /// The empty (identity) pipeline.
    pub fn new() -> PassPipeline {
        PassPipeline::default()
    }

    /// Append a pass.
    pub fn with(mut self, p: Box<dyn Pass>) -> PassPipeline {
        self.passes.push(p);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// Run every pass in order; inapplicable passes are skipped. Returns
    /// the final graph plus the names of the passes that actually fired.
    pub fn run(&self, g: &PipelineGraph) -> (PipelineGraph, Vec<&'static str>) {
        let mut cur = g.clone();
        let mut applied = Vec::new();
        for p in &self.passes {
            if let Some(next) = p.apply(&cur) {
                applied.push(p.name());
                cur = next;
            }
        }
        (cur, applied)
    }
}

/// Is this a retrieval-class stage a prefetch may hoist? Context
/// *gathering* (vector retrieval, web search) commutes across the
/// stages between gathers; LLM stages do not (their output feeds the
/// next stage's input).
fn retrieval_class(kind: &ComponentKind) -> bool {
    matches!(kind, ComponentKind::Retriever | ComponentKind::WebSearch)
}

/// Speculative prefetch: rewrite a serial chain `P → X1 → … → Xm → C`
/// (m ≥ 2, every `Xi` retrieval-class on unit-probability forward
/// edges) into `P →fork→ {X1 … Xm} →join(C)`. All retrievals start the
/// moment `P` commits instead of waiting on each other, cutting the
/// chain's critical path from Σ(Xi) to max(Xi) at identical resource
/// demand — each branch still carries full flow through the LP.
///
/// `join` is the barrier installed on `C`: [`JoinSpec::all`] (default)
/// fuses every branch's context; [`JoinSpec::first_k`] races the
/// branches and cancels the stragglers via the existing FirstK
/// cancellation in the DES and the live controller.
pub struct SpeculativePrefetch {
    pub join: JoinSpec,
}

impl Default for SpeculativePrefetch {
    fn default() -> Self {
        SpeculativePrefetch { join: JoinSpec::all() }
    }
}

impl SpeculativePrefetch {
    fn apply_once(&self, g: &PipelineGraph) -> Option<PipelineGraph> {
        let adj = g.adjacency();
        let prefetchable = |id: NodeId| -> bool {
            let n = g.node(id);
            if !retrieval_class(&n.kind) || n.join.is_some() || n.stateful || n.gamma != 1.0 {
                return false;
            }
            if adj.in_edges(id).len() != 1 || adj.out_edges(id).len() != 1 {
                return false;
            }
            let e_in = &g.edges[adj.in_edges(id)[0]];
            let e_out = &g.edges[adj.out_edges(id)[0]];
            !e_in.back_edge
                && !e_in.is_fork()
                && e_in.prob() == 1.0
                && !e_out.back_edge
                && !e_out.is_fork()
                && e_out.prob() == 1.0
        };
        for p in &g.nodes {
            if p.id == g.sink || g.is_fork_node(p.id) {
                continue;
            }
            for &ei0 in adj.out_edges(p.id) {
                let e0 = &g.edges[ei0];
                if e0.is_fork() || e0.back_edge || e0.prob() != 1.0 {
                    continue;
                }
                // Maximal run of prefetchable stages after `p`.
                let mut chain = Vec::new();
                let mut cur = e0.to;
                while prefetchable(cur) && chain.len() <= g.nodes.len() {
                    chain.push(cur);
                    cur = g.edges[adj.out_edges(cur)[0]].to;
                }
                if chain.len() < 2 {
                    continue;
                }
                let c = cur; // the stage that commits on the gathered context
                if c == g.sink || c == p.id || g.node(c).join.is_some() || g.is_fork_node(c) {
                    continue;
                }
                // The barrier's forward inflow must be exactly the chain
                // exit, or the join annotation would be ambiguous.
                let fwd_in =
                    adj.in_edges(c).iter().filter(|&&i| !g.edges[i].back_edge).count();
                if fwd_in != 1 {
                    continue;
                }
                return Some(self.rewrite(g, p.id, &chain, c, ei0, &adj));
            }
        }
        None
    }

    fn rewrite(
        &self,
        g: &PipelineGraph,
        p: NodeId,
        chain: &[NodeId],
        c: NodeId,
        entry_edge: usize,
        adj: &super::graph::Adjacency,
    ) -> PipelineGraph {
        let mut removed = vec![entry_edge];
        for &x in chain {
            removed.push(adj.out_edges(x)[0]);
        }
        let mut nodes = g.nodes.clone();
        nodes[c.0].join = Some(self.join);
        let mut edges: Vec<EdgeSpec> = Vec::with_capacity(g.edges.len() + chain.len());
        for (i, e) in g.edges.iter().enumerate() {
            if i == entry_edge {
                // Fork edges in chain order, then the branch→barrier edges.
                for &x in chain {
                    edges.push(EdgeSpec { from: p, to: x, kind: EdgeKind::Fork, back_edge: false });
                }
                for &x in chain {
                    edges.push(EdgeSpec::route(x, c, 1.0));
                }
                continue;
            }
            if removed.contains(&i) {
                continue;
            }
            edges.push(e.clone());
        }
        PipelineGraph { name: g.name.clone(), nodes, edges, source: g.source, sink: g.sink }
    }
}

impl Pass for SpeculativePrefetch {
    fn name(&self) -> &'static str {
        "speculative-prefetch"
    }

    fn apply(&self, g: &PipelineGraph) -> Option<PipelineGraph> {
        let mut cur = g.clone();
        let mut applied = false;
        while let Some(next) = self.apply_once(&cur) {
            cur = next;
            applied = true;
        }
        if !applied {
            return None;
        }
        cur.name = format!("{}+prefetch", g.name);
        Some(cur)
    }
}

/// Stage fusion: merge an adjacent pair `A → B` of cheap, co-locatable
/// stages into one node, eliminating a queue + dispatch hop between
/// them. Conservative by construction — a pair fuses only when `A`'s
/// single `Route(1.0)` forward edge is `B`'s single in-edge, both kinds
/// are in the `fusable` allowlist, neither is stateful/sharded/joined,
/// and `A` carries no amplification, cache, quantization, or degrade
/// knob (`B`'s knobs survive on the fused node). The fused node becomes
/// a [`ComponentKind::Custom`] stage whose α is re-profiled, with the
/// pair's resource demands summed so the LP still pays for both stages.
pub struct StageFusion {
    pub fusable: Vec<ComponentKind>,
}

impl Default for StageFusion {
    fn default() -> Self {
        StageFusion {
            fusable: vec![
                ComponentKind::Rewriter,
                ComponentKind::Classifier,
                ComponentKind::Grader,
                ComponentKind::Critic,
                ComponentKind::Retriever,
            ],
        }
    }
}

impl StageFusion {
    fn fuse_once(&self, g: &PipelineGraph) -> Option<PipelineGraph> {
        let adj = g.adjacency();
        for (ei, e) in g.edges.iter().enumerate() {
            if e.is_fork() || e.back_edge || e.prob() != 1.0 {
                continue;
            }
            let (a, b) = (e.from, e.to);
            if a == b || a == g.source || a == g.sink || b == g.source || b == g.sink {
                continue;
            }
            let (an, bn) = (g.node(a), g.node(b));
            if adj.out_edges(a).len() != 1 || adj.in_edges(b).len() != 1 {
                continue;
            }
            if !self.fusable.contains(&an.kind) || !self.fusable.contains(&bn.kind) {
                continue;
            }
            if an.stateful || bn.stateful || an.join.is_some() || bn.join.is_some() {
                continue;
            }
            if an.shards != 1 || bn.shards != 1 || an.gamma != 1.0 {
                continue;
            }
            if an.cache_hit_rate != 0.0 || an.quantized || an.degrade != DegradeKnob::None {
                continue;
            }
            return Some(fuse_pair(g, ei, a, b));
        }
        None
    }
}

fn fuse_pair(g: &PipelineGraph, fused_edge: usize, a: NodeId, b: NodeId) -> PipelineGraph {
    let (an, bn) = (g.node(a), g.node(b));
    // Per-kind resource sum: one co-located instance hosts both stages.
    let mut resources = an.resources.clone();
    for &(k, v) in &bn.resources {
        if let Some(slot) = resources.iter_mut().find(|(rk, _)| *rk == k) {
            slot.1 += v;
        } else {
            resources.push((k, v));
        }
    }
    let fused = NodeSpec {
        id: a,
        name: format!("{}+{}", an.name, bn.name),
        kind: ComponentKind::Custom(format!("{}+{}", an.kind.name(), bn.kind.name())),
        stateful: false,
        base_instances: an.base_instances.max(bn.base_instances),
        shards: 1,
        cache_hit_rate: bn.cache_hit_rate,
        quantized: bn.quantized,
        degrade: bn.degrade,
        join: None,
        resources,
        alpha: vec![], // the fused stage has a new cost profile — re-profiled
        gamma: bn.gamma,
        streamable: bn.streamable,
    };
    let a_final = if a.0 > b.0 { NodeId(a.0 - 1) } else { a };
    let remap = |id: NodeId| -> NodeId {
        if id == b {
            a_final
        } else if id.0 > b.0 {
            NodeId(id.0 - 1)
        } else {
            id
        }
    };
    let mut nodes: Vec<NodeSpec> = Vec::with_capacity(g.nodes.len() - 1);
    for n in &g.nodes {
        if n.id == b {
            continue;
        }
        let mut n2 = if n.id == a { fused.clone() } else { n.clone() };
        n2.id = remap(n.id);
        nodes.push(n2);
    }
    let edges: Vec<EdgeSpec> = g
        .edges
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != fused_edge)
        .map(|(_, e)| EdgeSpec {
            from: remap(e.from),
            to: remap(e.to),
            kind: e.kind,
            back_edge: e.back_edge,
        })
        .collect();
    PipelineGraph {
        name: g.name.clone(),
        nodes,
        edges,
        source: remap(g.source),
        sink: remap(g.sink),
    }
}

impl Pass for StageFusion {
    fn name(&self) -> &'static str {
        "stage-fusion"
    }

    fn apply(&self, g: &PipelineGraph) -> Option<PipelineGraph> {
        let mut cur = g.clone();
        let mut applied = false;
        while let Some(next) = self.fuse_once(&cur) {
            cur = next;
            applied = true;
        }
        if !applied {
            return None;
        }
        cur.name = format!("{}+fused", g.name);
        Some(cur)
    }
}

/// Automatic `*_sequential` control generation: rewrite a graph with
/// exactly one fork region into its serialized equivalent — the same
/// nodes, with the branches chained end to end in fork-edge order and
/// the join annotation dropped. This mechanically derives the
/// `hybrid-rag-seq` / `mq-rag-seq` baseline apps from their forked
/// originals (pinned bit-identical to the retired hand-written
/// constructions), so every future forked app gets its equal-allocation
/// control for free.
///
/// Conservative: applies only to graphs with exactly one fork group
/// whose every branch exits into the join over a single `Route(1.0)`
/// edge; anything richer returns `None`.
pub struct Sequentialize;

impl Pass for Sequentialize {
    fn name(&self) -> &'static str {
        "sequentialize"
    }

    fn apply(&self, g: &PipelineGraph) -> Option<PipelineGraph> {
        let adj = g.adjacency();
        let fork_map = fork_groups_dense(g, &adj);
        let mut groups = fork_map.iter().flatten();
        let fg = groups.next()?.clone();
        if groups.next().is_some() {
            return None; // nested/multiple regions: out of scope
        }
        let n = g.nodes.len();
        let mut branch_members: Vec<Vec<bool>> = Vec::with_capacity(fg.targets.len());
        let mut exits: Vec<NodeId> = Vec::with_capacity(fg.targets.len());
        for &t in &fg.targets {
            let r = forward_reachable(g, &adj, t, Some(fg.join));
            let mut members = vec![false; n];
            for (i, &in_r) in r.iter().enumerate() {
                if in_r && i != fg.join.0 {
                    members[i] = true;
                }
            }
            // The branch must drain into the join over ONE full-flow edge;
            // that edge's source becomes the link to the next branch.
            let mut exit: Option<NodeId> = None;
            for e in &g.edges {
                if e.to == fg.join && members[e.from.0] && !e.back_edge {
                    if exit.is_some() || e.is_fork() || e.prob() != 1.0 {
                        return None;
                    }
                    exit = Some(e.from);
                }
            }
            exits.push(exit?);
            branch_members.push(members);
        }
        let mut nodes = g.nodes.clone();
        nodes[fg.join.0].join = None;
        let mut used = vec![false; g.edges.len()];
        for &ei in &fg.edges {
            used[ei] = true;
        }
        let mut edges: Vec<EdgeSpec> = Vec::with_capacity(g.edges.len());
        edges.push(EdgeSpec::route(fg.fork, fg.targets[0], 1.0));
        for (bi, members) in branch_members.iter().enumerate() {
            // Branch-interior edges keep their declaration order.
            for (i, e) in g.edges.iter().enumerate() {
                if !e.back_edge && members[e.from.0] && members[e.to.0] {
                    edges.push(e.clone());
                    used[i] = true;
                }
            }
            for (i, e) in g.edges.iter().enumerate() {
                if e.to == fg.join && members[e.from.0] {
                    used[i] = true; // the old exit edge, replaced by the link
                }
            }
            let next = if bi + 1 < fg.targets.len() { fg.targets[bi + 1] } else { fg.join };
            edges.push(EdgeSpec::route(exits[bi], next, 1.0));
        }
        for (i, e) in g.edges.iter().enumerate() {
            if !used[i] {
                edges.push(e.clone());
            }
        }
        Some(PipelineGraph {
            name: format!("{}-seq", g.name),
            nodes,
            edges,
            source: g.source,
            sink: g.sink,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;
    use crate::spec::graph::ResourceKind;

    #[test]
    fn the_default_pipeline_is_empty_and_the_identity() {
        let g = apps::hybrid_rag();
        let pipe = PassPipeline::new();
        assert!(pipe.is_empty());
        let (out, applied) = pipe.run(&g);
        assert!(applied.is_empty());
        assert_eq!(format!("{out:?}"), format!("{g:?}"), "identity down to the bits");
    }

    #[test]
    fn prefetch_reconstructs_the_hand_built_fork_from_the_serial_chain() {
        let seq = apps::hybrid_rag_sequential();
        let p = SpeculativePrefetch::default().apply(&seq).expect("retrieval chain found");
        p.validate().unwrap();
        assert_eq!(p.name, "hybrid-rag-seq+prefetch");
        let hy = apps::hybrid_rag();
        assert_eq!(format!("{:?}", p.nodes), format!("{:?}", hy.nodes));
        assert_eq!(format!("{:?}", p.edges), format!("{:?}", hy.edges));
    }

    #[test]
    fn prefetch_preserves_visit_rates() {
        let seq = apps::hybrid_rag_sequential();
        let p = SpeculativePrefetch::default().apply(&seq).unwrap();
        let (vs, vp) = (seq.visit_rates(), p.visit_rates());
        for n in &seq.nodes {
            assert!(
                (vs[n.id.0] - vp[n.id.0]).abs() < 1e-9,
                "{}: serial {} vs prefetched {}",
                n.name,
                vs[n.id.0],
                vp[n.id.0]
            );
        }
    }

    #[test]
    fn prefetched_graph_profiles_identically_to_the_hand_built_fork() {
        // Same structure + same seed → the profiler's RNG stream, and
        // with it every sampled service time, is bit-identical.
        let p = SpeculativePrefetch::default().apply(&apps::hybrid_rag_sequential()).unwrap();
        let hy = apps::hybrid_rag();
        let pa = crate::profile::profile_graph(&p, 400, 11);
        let pb = crate::profile::profile_graph(&hy, 400, 11);
        assert_eq!(pa.edge_probs, pb.edge_probs);
        for n in hy.work_nodes() {
            assert_eq!(
                pa.mean_service[&n.id].to_bits(),
                pb.mean_service[&n.id].to_bits(),
                "{}",
                n.name
            );
        }
    }

    #[test]
    fn prefetch_preserves_the_lp_objective() {
        // Structurally identical graphs profile identically (above), so
        // the allocation LP — same columns, same rows, same priors —
        // must land on the same objective to the bit. Against the chain
        // as written the fork is a latency structure, not a capacity
        // one: the throughput ceiling stays in the same band.
        let p = SpeculativePrefetch::default().apply(&apps::hybrid_rag_sequential()).unwrap();
        let a = crate::alloc::flow::plan_for(&p, 2000, 5);
        let b = crate::alloc::flow::plan_for(&apps::hybrid_rag(), 2000, 5);
        assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
        let seq = crate::alloc::flow::plan_for(&apps::hybrid_rag_sequential(), 2000, 5);
        let ratio = a.throughput / seq.throughput;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn prefetched_graph_simulates_identically_to_the_hand_built_fork() {
        // DES output distributions: same structure + same seed → the
        // event stream, and with it every latency sample, is
        // bit-identical to the hand-built fork app.
        use crate::sim::{run_point, SystemKind};
        let p = SpeculativePrefetch::default().apply(&apps::hybrid_rag_sequential()).unwrap();
        let a = run_point(SystemKind::Harmonia, p, 32.0, 300, Some(2.0), 9);
        let b = run_point(SystemKind::Harmonia, apps::hybrid_rag(), 32.0, 300, Some(2.0), 9);
        assert_eq!(a.report.p50.to_bits(), b.report.p50.to_bits());
        assert_eq!(a.report.p99.to_bits(), b.report.p99.to_bits());
        assert_eq!(a.report.mean_latency.to_bits(), b.report.mean_latency.to_bits());
        assert_eq!(a.report.throughput.to_bits(), b.report.throughput.to_bits());
    }

    #[test]
    fn prefetch_requires_an_adjacent_retrieval_chain() {
        let pass = SpeculativePrefetch::default();
        for name in ["v-rag", "c-rag", "s-rag", "a-rag", "mq-rag-seq"] {
            let g = apps::by_name(name).unwrap();
            assert!(pass.apply(&g).is_none(), "{name} has no 2-stage retrieval chain");
        }
    }

    #[test]
    fn fusion_fuses_the_rewrite_retrieve_pairs_of_mq_rag_seq() {
        let seq = apps::multiquery_rag_sequential(3);
        let f = StageFusion::default().apply(&seq).expect("three fusable pairs");
        f.validate().unwrap();
        assert_eq!(f.name, "mq-rag-seq+fused");
        assert_eq!(f.work_nodes().count(), 4, "3 fused stages + generator");
        let fused = f.node_by_name("rewriter_q0+retriever_q0").expect("fused name");
        assert!(matches!(fused.kind, ComponentKind::Custom(_)));
        // Resource demands are summed — the LP still pays for both stages.
        assert_eq!(fused.demand_for(ResourceKind::Gpu), 1.0);
        assert_eq!(fused.demand_for(ResourceKind::Cpu), 8.0);
        assert_eq!(fused.demand_for(ResourceKind::Ram), 112.0);
        // B's degrade knob survives on the fused stage.
        assert_eq!(fused.degrade, DegradeKnob::ShrinkTopK);
        // Flow is preserved: every surviving stage still runs once.
        let v = f.visit_rates();
        assert!((v[f.sink.0] - 1.0).abs() < 1e-9, "sink {}", v[f.sink.0]);
        for n in f.work_nodes() {
            assert!((v[n.id.0] - 1.0).abs() < 1e-9, "{}: {}", n.name, v[n.id.0]);
        }
    }

    #[test]
    fn fusion_never_crosses_generator_or_websearch_boundaries() {
        let pass = StageFusion::default();
        assert!(pass.apply(&apps::vanilla_rag()).is_none(), "retr→gen must not fuse");
        assert!(
            pass.apply(&apps::hybrid_rag_sequential()).is_none(),
            "retr→web (external I/O) must not fuse"
        );
    }

    #[test]
    fn sequentialize_requires_exactly_one_fork_region() {
        assert!(Sequentialize.apply(&apps::vanilla_rag()).is_none());
        assert!(Sequentialize.apply(&apps::corrective_rag()).is_none());
        assert!(Sequentialize.apply(&apps::hybrid_rag_sequential()).is_none());
    }

    #[test]
    fn passes_compose_and_report_in_order() {
        // Round trip: serialize the fork, then prefetch re-discovers it.
        let (out, applied) = PassPipeline::new()
            .with(Box::new(Sequentialize))
            .with(Box::new(SpeculativePrefetch::default()))
            .run(&apps::hybrid_rag());
        assert_eq!(applied, vec!["sequentialize", "speculative-prefetch"]);
        out.validate().unwrap();
        assert!(out.has_forks(), "prefetch re-forked the serialized chain");
        let v = out.visit_rates();
        assert!((v[out.sink.0] - 1.0).abs() < 1e-9);
    }
}
