//! Pipeline graph representation: the machine-readable control-flow
//! structure the deployment and runtime layers reason over.
//!
//! Mirrors the paper's model (§3.2): nodes are components with
//! per-resource throughput coefficients α_{i,k} and amplification factors
//! γ_i; edges carry typed routing semantics ([`EdgeKind`]): probabilistic
//! `Route(p)` edges (exactly one successor per visit) or parallel `Fork`
//! edges (every successor runs as a sibling subtask, reconverging at a
//! [`JoinSpec`]-annotated node). Back edges (recursion) are first-class
//! and folded into effective visit rates for the allocation LP.

use std::collections::HashMap;

/// Resource types K in the allocation model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// CPU cores.
    Cpu,
    /// Whole GPUs.
    Gpu,
    /// RAM in GiB.
    Ram,
}

impl ResourceKind {
    pub const ALL: [ResourceKind; 3] = [ResourceKind::Cpu, ResourceKind::Gpu, ResourceKind::Ram];

    pub fn name(self) -> &'static str {
        match self {
            ResourceKind::Cpu => "CPU",
            ResourceKind::Gpu => "GPU",
            ResourceKind::Ram => "RAM",
        }
    }
}

/// What a component *is* — used to pick live executors and default latency
/// models. New kinds integrate without framework changes via `Custom`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ComponentKind {
    /// Pipeline entry (admission); zero-cost.
    Source,
    /// Pipeline exit (response); zero-cost.
    Sink,
    /// Vector retrieval (CPU/memory-bound).
    Retriever,
    /// LLM generation (GPU-bound, prefill+decode).
    Generator,
    /// LLM-based relevance grader (GPU, single output token).
    Grader,
    /// LLM-based output critic (GPU, single output token).
    Critic,
    /// LLM-based query rewriter (GPU, short generation).
    Rewriter,
    /// External web search (I/O bound).
    WebSearch,
    /// Query complexity classifier (small model).
    Classifier,
    /// User-defined component with a latency profile supplied at
    /// registration — the "library-agnostic integration" hook.
    Custom(String),
}

impl ComponentKind {
    pub fn name(&self) -> &str {
        match self {
            ComponentKind::Source => "source",
            ComponentKind::Sink => "sink",
            ComponentKind::Retriever => "retriever",
            ComponentKind::Generator => "generator",
            ComponentKind::Grader => "grader",
            ComponentKind::Critic => "critic",
            ComponentKind::Rewriter => "rewriter",
            ComponentKind::WebSearch => "websearch",
            ComponentKind::Classifier => "classifier",
            ComponentKind::Custom(s) => s,
        }
    }

    /// Does this component run on the GPU-style resource?
    pub fn gpu_bound(&self) -> bool {
        matches!(
            self,
            ComponentKind::Generator
                | ComponentKind::Grader
                | ComponentKind::Critic
                | ComponentKind::Rewriter
                | ComponentKind::Classifier
        )
    }
}

/// Node handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Which overload-degradation knob this component exposes, if any
/// (declared per node, like `stateful` or `shards`; acted on only when
/// the runtime's `sched::DegradePolicy` is enabled and the cluster is
/// overloaded — the default control plane never degrades).
///
/// Each knob trades a small quality delta for a large latency win under
/// burst load (RAGO-style per-stage degradation):
///
/// * [`DegradeKnob::ShrinkTopK`] — retrieval-style stages fetch fewer
///   documents (top-k shrinks with the overload level).
/// * [`DegradeKnob::SkipHop`] — optional quality hops (reranker, grader)
///   are bypassed entirely at severe overload; the pipeline takes the
///   success branch as if the hop had passed.
/// * [`DegradeKnob::CapIterations`] — recursive refinement loops
///   (critic → rewrite) exit after the current pass at severe overload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DegradeKnob {
    /// Never degraded (the default for every component).
    #[default]
    None,
    /// Shrink retrieval top-k under overload.
    ShrinkTopK,
    /// Skip this optional quality hop at severe overload.
    SkipHop,
    /// Stop re-entering the refinement loop at severe overload.
    CapIterations,
}

/// How an edge moves a request to its successor(s) — the typed-edge core
/// of the parallel-dataflow model.
///
/// * [`EdgeKind::Route`] — probabilistic routing p_{i,j}: exactly ONE
///   outgoing `Route` edge is taken per visit (the pre-fork semantics;
///   per-node `Route` probabilities must sum to 1).
/// * [`EdgeKind::Fork`] — parallel fan-out: EVERY outgoing `Fork` edge
///   fires, spawning one sibling subtask per branch. Fork edges carry
///   **full flow** (prob = 1 per branch) through the visit-rate fixed
///   point and the allocation LP — every branch must be provisioned.
///   Branches reconverge at a downstream node annotated with a
///   [`JoinSpec`]; a node's outgoing edges must be all-`Route` or
///   all-`Fork`, never mixed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeKind {
    /// Probabilistic routing with probability p (existing semantics).
    Route(f64),
    /// Parallel fan-out: this branch always runs.
    Fork,
}

impl EdgeKind {
    /// Flow fraction this edge carries per visit of its source: the
    /// routing probability for [`EdgeKind::Route`], and 1.0 for
    /// [`EdgeKind::Fork`] (every branch sees the full request stream).
    pub fn prob(&self) -> f64 {
        match self {
            EdgeKind::Route(p) => *p,
            EdgeKind::Fork => 1.0,
        }
    }

    pub fn is_fork(&self) -> bool {
        matches!(self, EdgeKind::Fork)
    }
}

/// When a join node releases its barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinPolicy {
    /// Wait for every branch (barrier join).
    All,
    /// Release when the first `k` branches arrive; the losing branches
    /// are cancelled (racing / speculative execution). `k` must satisfy
    /// `1 ≤ k ≤ branches`.
    FirstK(usize),
}

/// How the join combines the branch results ([`crate::exec::RagState`]s
/// on the live path; the DES carries no payload and ignores it).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MergePolicy {
    /// Union the branch retrieval results: doc ids deduplicated across
    /// branches (first occurrence wins), contexts concatenated
    /// branch-major with per-branch score order preserved; scalar fields
    /// take the first populated value.
    #[default]
    Union,
    /// Winner-takes-all: the first arriving branch's state is used
    /// verbatim (the natural pairing for `FirstK(1)` races).
    First,
}

/// Join annotation on a node: the barrier where fork branches reconverge.
/// The annotated node executes once per request, after the barrier
/// releases, on the merged state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JoinSpec {
    pub policy: JoinPolicy,
    pub merge: MergePolicy,
}

impl JoinSpec {
    /// Barrier join over every branch with [`MergePolicy::Union`].
    pub fn all() -> JoinSpec {
        JoinSpec { policy: JoinPolicy::All, merge: MergePolicy::Union }
    }

    /// Racing join: release after `k` arrivals, cancel the rest, keep
    /// the winner's state ([`MergePolicy::First`]).
    pub fn first_k(k: usize) -> JoinSpec {
        JoinSpec { policy: JoinPolicy::FirstK(k), merge: MergePolicy::First }
    }

    /// Branch arrivals needed to release the barrier, for a fork with
    /// `branches` branches.
    pub fn need(&self, branches: usize) -> usize {
        match self.policy {
            JoinPolicy::All => branches,
            JoinPolicy::FirstK(k) => k.min(branches),
        }
    }
}

/// One pipeline component plus its declarative constraints (§3.1
/// "Specifying workflow constraints").
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub id: NodeId,
    pub name: String,
    pub kind: ComponentKind,
    /// Recursive invocations must return to the same instance.
    pub stateful: bool,
    /// Minimum instances kept warm (cold-start protection).
    pub base_instances: usize,
    /// Index shards for partitioned components (retrieval scatter-gather):
    /// each request fans out to all `shards` partitions in parallel, each
    /// holding ~1/shards of the data. 1 = unsharded. The allocator sizes
    /// each shard's replica pool independently.
    pub shards: usize,
    /// Expected request-cache hit rate for this component (retrieval
    /// memoization): fraction of visits served from the query cache at a
    /// small fixed cost instead of a full pass. 0 = uncached. Set from
    /// the workload skew via `profile::models::zipf_hit_rate`; applied by
    /// the profiler and the DES through
    /// `profile::models::cache_service_factor`, so the LP priors and the
    /// autoscaler see cache-adjusted α.
    pub cache_hit_rate: f64,
    /// Whether this component's index scan runs scalar-quantized
    /// (`retrieval::Quantization::SQ8`: u8 codes + exact rescoring)
    /// instead of full f32. Applied by the profiler and the DES through
    /// `profile::models::quantized_service_factor`, so LP priors and
    /// simulated telemetry price the cheaper scan consistently. `false`
    /// (the default) is an exact identity — golden traces replay
    /// bit-identically.
    pub quantized: bool,
    /// Overload-degradation knob (see [`DegradeKnob`]); `None` for
    /// components that must always run at full fidelity.
    pub degrade: DegradeKnob,
    /// Barrier annotation: fork branches reconverge here (see
    /// [`JoinSpec`]). `None` for every ordinary node.
    pub join: Option<JoinSpec>,
    /// Per-instance resource demand (r constraint granularity).
    pub resources: Vec<(ResourceKind, f64)>,
    /// Throughput coefficient α_{i,k}: requests/sec per unit of resource k
    /// (profiled; these are the deploy-time priors).
    pub alpha: Vec<(ResourceKind, f64)>,
    /// Request amplification γ_i (>1 fan-out, <1 abridgement).
    pub gamma: f64,
    /// Whether the component can stream output to its successor.
    pub streamable: bool,
}

impl NodeSpec {
    pub fn alpha_for(&self, k: ResourceKind) -> f64 {
        self.alpha
            .iter()
            .find(|(rk, _)| *rk == k)
            .map(|(_, a)| *a)
            .unwrap_or(0.0)
    }

    pub fn demand_for(&self, k: ResourceKind) -> f64 {
        self.resources
            .iter()
            .find(|(rk, _)| *rk == k)
            .map(|(_, d)| *d)
            .unwrap_or(0.0)
    }
}

/// Directed edge with typed routing semantics ([`EdgeKind`]); `back_edge`
/// marks recursion (loops back toward an ancestor in the DAG backbone).
#[derive(Clone, Debug)]
pub struct EdgeSpec {
    pub from: NodeId,
    pub to: NodeId,
    pub kind: EdgeKind,
    pub back_edge: bool,
}

impl EdgeSpec {
    /// Convenience constructor for a forward `Route(p)` edge.
    pub fn route(from: NodeId, to: NodeId, p: f64) -> EdgeSpec {
        EdgeSpec { from, to, kind: EdgeKind::Route(p), back_edge: false }
    }

    /// Flow fraction carried per source visit (see [`EdgeKind::prob`]).
    pub fn prob(&self) -> f64 {
        self.kind.prob()
    }

    pub fn is_fork(&self) -> bool {
        self.kind.is_fork()
    }
}

/// Cached adjacency index over a [`PipelineGraph`]'s edge list: outgoing /
/// incoming edge indices per node, in edge-declaration order. Built once
/// (O(V+E)) and consulted by the hot loops that previously re-scanned the
/// whole edge list per step (DES branch sampling, the profiler's graph
/// walk, validation reachability). The graph's `nodes`/`edges` are public
/// and test code mutates them, so the index is an explicit snapshot the
/// caller owns rather than an embedded cache that could go stale.
#[derive(Clone, Debug)]
pub struct Adjacency {
    succ: Vec<Vec<usize>>,
    pred: Vec<Vec<usize>>,
}

impl Adjacency {
    pub fn new(g: &PipelineGraph) -> Adjacency {
        let n = g.nodes.len();
        let mut succ = vec![Vec::new(); n];
        let mut pred = vec![Vec::new(); n];
        for (i, e) in g.edges.iter().enumerate() {
            succ[e.from.0].push(i);
            pred[e.to.0].push(i);
        }
        Adjacency { succ, pred }
    }

    /// Outgoing edge indices of `node`, in edge-declaration order.
    pub fn out_edges(&self, node: NodeId) -> &[usize] {
        &self.succ[node.0]
    }

    /// Incoming edge indices of `node`, in edge-declaration order.
    pub fn in_edges(&self, node: NodeId) -> &[usize] {
        &self.pred[node.0]
    }
}

/// One fork region, resolved from a validated graph: the fork node, its
/// branch entry nodes (fork-edge order), and the join that reconverges
/// them. The DES and the live controller both drive their barrier
/// bookkeeping off this.
#[derive(Clone, Debug)]
pub struct ForkGroup {
    pub fork: NodeId,
    pub join: NodeId,
    /// Branch entry nodes, in fork-edge declaration order.
    pub targets: Vec<NodeId>,
    /// Fork edge indices, parallel to `targets`.
    pub edges: Vec<usize>,
    pub policy: JoinPolicy,
    pub merge: MergePolicy,
    /// Branch arrivals that release the barrier.
    pub need: usize,
}

/// The captured pipeline graph.
#[derive(Clone, Debug)]
pub struct PipelineGraph {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
    pub edges: Vec<EdgeSpec>,
    pub source: NodeId,
    pub sink: NodeId,
}

#[derive(Debug, PartialEq)]
pub enum ValidationError {
    BadProbabilitySum { node: String, sum: f64 },
    Unreachable { node: String },
    NoPathToSink { node: String },
    BadGamma { node: String, gamma: f64 },
    BadShards { node: String },
    BadCacheHitRate { node: String, rate: f64 },
    SelfLoopWithoutBackEdge { node: String },
    DuplicateName(String),
    /// A node mixes `Fork` and `Route` outgoing edges.
    MixedEdgeKinds { node: String },
    /// A `Fork` edge is marked as a back edge (speculative re-entry into
    /// the past is not a defined dataflow).
    ForkIntoBackEdge { node: String },
    /// A fork edge points directly at a join node — a branch with no
    /// work in it.
    EmptyForkBranch { node: String },
    /// Fewer than two branches, or the branches never reconverge on a
    /// single join-annotated node.
    UnbalancedFork { node: String },
    /// A join was found, but the named branch never reaches it.
    JoinMissingBranch { join: String, branch: String },
    /// A node inside a fork region has an edge escaping the region
    /// (e.g. a branch path that bypasses the join toward the sink).
    ForkBranchEscapes { node: String, via: String },
    /// Two branches of the same fork share an intermediate node — the
    /// sibling subtasks would collide on per-(request, node) state.
    OverlappingForkBranches { node: String },
    /// A back edge enters or leaves the interior of a fork region;
    /// recursion must wrap the whole fork/join, not cut into it.
    BackEdgeInForkRegion { node: String },
    /// `FirstK(k)` with k = 0 or k greater than the branch count.
    BadFirstK { node: String, k: usize, branches: usize },
    /// A `JoinSpec`-annotated node no fork resolves to, or a join with a
    /// forward in-edge arriving from outside its fork region.
    JoinWithoutFork { node: String },
    /// Two different forks resolve to the same join node — the barrier's
    /// branch count (and with it the LP's inflow scale) would be
    /// ambiguous.
    SharedJoin { node: String },
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValidationError::BadProbabilitySum { node, sum } => {
                write!(f, "outgoing probabilities of '{node}' sum to {sum}, expected 1")
            }
            ValidationError::Unreachable { node } => write!(f, "'{node}' unreachable from source"),
            ValidationError::NoPathToSink { node } => write!(f, "'{node}' has no path to sink"),
            ValidationError::BadGamma { node, gamma } => {
                write!(f, "'{node}' has non-positive gamma {gamma}")
            }
            ValidationError::BadShards { node } => {
                write!(f, "'{node}' has zero shards (must be >= 1)")
            }
            ValidationError::BadCacheHitRate { node, rate } => {
                write!(f, "'{node}' has cache hit rate {rate} outside [0, 1)")
            }
            ValidationError::SelfLoopWithoutBackEdge { node } => {
                write!(f, "'{node}' has a self loop not marked as back edge")
            }
            ValidationError::DuplicateName(n) => write!(f, "duplicate component name '{n}'"),
            ValidationError::MixedEdgeKinds { node } => {
                write!(f, "'{node}' mixes Fork and Route outgoing edges")
            }
            ValidationError::ForkIntoBackEdge { node } => {
                write!(f, "'{node}' has a Fork edge marked as a back edge")
            }
            ValidationError::EmptyForkBranch { node } => {
                write!(f, "'{node}' forks directly into a join node (empty branch)")
            }
            ValidationError::UnbalancedFork { node } => {
                write!(f, "fork at '{node}' is unbalanced: branches do not reconverge on one join")
            }
            ValidationError::JoinMissingBranch { join, branch } => {
                write!(f, "join '{join}' is not reachable from fork branch '{branch}'")
            }
            ValidationError::ForkBranchEscapes { node, via } => {
                write!(f, "fork region of '{node}' leaks: '{via}' has an edge bypassing the join")
            }
            ValidationError::OverlappingForkBranches { node } => {
                write!(f, "branches of fork '{node}' overlap on shared nodes")
            }
            ValidationError::BackEdgeInForkRegion { node } => {
                write!(f, "back edge touches the interior of the fork region at '{node}'")
            }
            ValidationError::BadFirstK { node, k, branches } => {
                write!(f, "join '{node}' wants FirstK({k}) but the fork has {branches} branches")
            }
            ValidationError::JoinWithoutFork { node } => {
                write!(f, "join '{node}' is not the reconvergence point of any fork")
            }
            ValidationError::SharedJoin { node } => {
                write!(f, "join '{node}' reconverges more than one fork (ambiguous barrier)")
            }
        }
    }
}

impl PipelineGraph {
    pub fn node(&self, id: NodeId) -> &NodeSpec {
        &self.nodes[id.0]
    }

    pub fn node_by_name(&self, name: &str) -> Option<&NodeSpec> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Build the adjacency index for this graph's current edge list (see
    /// [`Adjacency`]). Hot loops should build this once and reuse it
    /// instead of calling [`PipelineGraph::successors`] per step.
    pub fn adjacency(&self) -> Adjacency {
        Adjacency::new(self)
    }

    /// Build the full dense analysis bundle — adjacency, topological
    /// order, dominator/post-dominator trees, the fork-region tree,
    /// join scales, visit rates, and edge flows — in one pass (see
    /// [`super::analysis::AnalyzedGraph`]). Deploy-time consumers (LP
    /// construction, the profiler, the DES, the live controller) call
    /// this once per graph and index the shared tables instead of
    /// re-deriving their own traversals.
    pub fn analyze(&self) -> super::analysis::AnalyzedGraph {
        super::analysis::AnalyzedGraph::new(self)
    }

    pub fn successors(&self, id: NodeId) -> impl Iterator<Item = &EdgeSpec> {
        self.edges.iter().filter(move |e| e.from == id)
    }

    pub fn predecessors(&self, id: NodeId) -> impl Iterator<Item = &EdgeSpec> {
        self.edges.iter().filter(move |e| e.to == id)
    }

    /// Components that do real work (not source/sink).
    pub fn work_nodes(&self) -> impl Iterator<Item = &NodeSpec> {
        self.nodes
            .iter()
            .filter(|n| !matches!(n.kind, ComponentKind::Source | ComponentKind::Sink))
    }

    /// Does the workflow contain conditional branching (Table 1)? Only
    /// `Route` fan-out counts — a fork is parallel dataflow, not a
    /// conditional.
    pub fn has_conditionals(&self) -> bool {
        let mut out: HashMap<NodeId, usize> = HashMap::new();
        for e in self.edges.iter().filter(|e| !e.is_fork()) {
            *out.entry(e.from).or_insert(0) += 1;
        }
        out.values().any(|&c| c > 1)
    }

    /// Does the workflow contain recursion (Table 1)?
    pub fn has_recursion(&self) -> bool {
        self.edges.iter().any(|e| e.back_edge)
    }

    /// Does the workflow contain parallel (fork/join) dataflow?
    pub fn has_forks(&self) -> bool {
        self.edges.iter().any(|e| e.is_fork())
    }

    /// Is `id` a fork node (its outgoing edges are `Fork` edges)?
    pub fn is_fork_node(&self, id: NodeId) -> bool {
        self.successors(id).any(|e| e.is_fork())
    }

    /// Per-node inflow scales for the visit-rate fixed point and the
    /// allocation LP: a join's branch-completion edges each carry full
    /// flow, but the barrier merges them back into ONE request — so the
    /// join's effective workload is the summed inflow divided by the
    /// resolved fork's **branch count** (NOT its in-edge count: a branch
    /// that routes probabilistically may reach the join over several
    /// edges whose flows already sum to one branch's worth). 1.0 for
    /// every ordinary node; validation guarantees each join resolves to
    /// exactly one fork ([`ValidationError::SharedJoin`]), keeping the
    /// static scale well-defined.
    pub fn join_scales(&self) -> Vec<f64> {
        let adj = self.adjacency();
        let fork_map = super::analysis::fork_groups_dense(self, &adj);
        super::analysis::join_scales_from(self, &fork_map)
    }

    /// Convenience single-node accessor for [`PipelineGraph::join_scales`]
    /// (callers iterating many nodes should compute the vector once).
    pub fn join_in_scale(&self, id: NodeId) -> f64 {
        self.join_scales()[id.0]
    }

    /// Resolve every fork node to its [`ForkGroup`] (branch entries +
    /// join + barrier policy). Best-effort on unvalidated graphs: forks
    /// whose join cannot be resolved are omitted — `validate` rejects
    /// such graphs with a precise error.
    ///
    /// Compatibility wrapper over the dense index
    /// (`super::analysis::fork_groups_dense`); hot paths should use
    /// [`PipelineGraph::analyze`] and index `fork_map` by node id
    /// instead of hashing.
    pub fn fork_groups(&self) -> HashMap<NodeId, ForkGroup> {
        let adj = self.adjacency();
        super::analysis::fork_groups_dense(self, &adj)
            .into_iter()
            .flatten()
            .map(|fg| (fg.fork, fg))
            .collect()
    }

    /// Structural validation; run by the builder and unit tests.
    pub fn validate(&self) -> Result<(), ValidationError> {
        // Unique names.
        let mut seen = std::collections::HashSet::new();
        for n in &self.nodes {
            if !seen.insert(n.name.clone()) {
                return Err(ValidationError::DuplicateName(n.name.clone()));
            }
            if n.gamma <= 0.0 {
                return Err(ValidationError::BadGamma { node: n.name.clone(), gamma: n.gamma });
            }
            if n.shards == 0 {
                return Err(ValidationError::BadShards { node: n.name.clone() });
            }
            if !(0.0..1.0).contains(&n.cache_hit_rate) {
                return Err(ValidationError::BadCacheHitRate {
                    node: n.name.clone(),
                    rate: n.cache_hit_rate,
                });
            }
        }
        let adj = self.adjacency();
        // Edge-kind discipline + probability sums (Route nodes only).
        for n in &self.nodes {
            if n.id == self.sink {
                continue;
            }
            let succ: Vec<&EdgeSpec> =
                adj.out_edges(n.id).iter().map(|&i| &self.edges[i]).collect();
            let forks = succ.iter().filter(|e| e.is_fork()).count();
            if forks > 0 && forks < succ.len() {
                return Err(ValidationError::MixedEdgeKinds { node: n.name.clone() });
            }
            if forks == 0 && !succ.is_empty() {
                let sum: f64 = succ.iter().map(|e| e.prob()).sum();
                if (sum - 1.0).abs() > 1e-6 {
                    return Err(ValidationError::BadProbabilitySum { node: n.name.clone(), sum });
                }
            }
        }
        for e in &self.edges {
            if e.from == e.to && !e.back_edge {
                return Err(ValidationError::SelfLoopWithoutBackEdge {
                    node: self.node(e.from).name.clone(),
                });
            }
        }
        self.validate_forks(&adj)?;
        // Reachability from source (forward edges and back edges both count).
        let mut reach = vec![false; self.nodes.len()];
        let mut stack = vec![self.source];
        reach[self.source.0] = true;
        while let Some(u) = stack.pop() {
            for &ei in adj.out_edges(u) {
                let e = &self.edges[ei];
                if !reach[e.to.0] {
                    reach[e.to.0] = true;
                    stack.push(e.to);
                }
            }
        }
        for n in &self.nodes {
            if !reach[n.id.0] {
                return Err(ValidationError::Unreachable { node: n.name.clone() });
            }
        }
        // Path to sink.
        let mut to_sink = vec![false; self.nodes.len()];
        to_sink[self.sink.0] = true;
        let mut changed = true;
        while changed {
            changed = false;
            for e in &self.edges {
                if to_sink[e.to.0] && !to_sink[e.from.0] {
                    to_sink[e.from.0] = true;
                    changed = true;
                }
            }
        }
        for n in &self.nodes {
            if !to_sink[n.id.0] {
                return Err(ValidationError::NoPathToSink { node: n.name.clone() });
            }
        }
        Ok(())
    }

    /// Fork/join structural checks: balanced forks, joins reachable from
    /// every branch, closed and disjoint branch regions, no back edges
    /// cutting into a region, `FirstK` within bounds, no orphan joins.
    fn validate_forks(&self, adj: &Adjacency) -> Result<(), ValidationError> {
        let mut matched_joins: HashMap<NodeId, Vec<NodeId>> = HashMap::new(); // join → forks
        let mut region_of: HashMap<NodeId, Vec<bool>> = HashMap::new(); // fork → region
        for n in &self.nodes {
            let fork_edges: Vec<&EdgeSpec> = adj
                .out_edges(n.id)
                .iter()
                .map(|&i| &self.edges[i])
                .filter(|e| e.is_fork())
                .collect();
            if fork_edges.is_empty() {
                continue;
            }
            for e in &fork_edges {
                if e.back_edge {
                    return Err(ValidationError::ForkIntoBackEdge { node: n.name.clone() });
                }
                if self.node(e.to).join.is_some() {
                    return Err(ValidationError::EmptyForkBranch { node: n.name.clone() });
                }
            }
            let targets: Vec<NodeId> = fork_edges.iter().map(|e| e.to).collect();
            if targets.len() < 2 {
                return Err(ValidationError::UnbalancedFork { node: n.name.clone() });
            }
            let Some(join) = super::analysis::resolve_join(self, adj, &targets) else {
                return Err(ValidationError::UnbalancedFork { node: n.name.clone() });
            };
            for &t in &targets {
                if !super::analysis::forward_reachable(self, adj, t, None)[join.0] {
                    return Err(ValidationError::JoinMissingBranch {
                        join: self.node(join).name.clone(),
                        branch: self.node(t).name.clone(),
                    });
                }
            }
            let spec = self.node(join).join.expect("resolved join is annotated");
            if let JoinPolicy::FirstK(k) = spec.policy {
                if k == 0 || k > targets.len() {
                    return Err(ValidationError::BadFirstK {
                        node: self.node(join).name.clone(),
                        k,
                        branches: targets.len(),
                    });
                }
            }
            // Branch regions: reachable from each target, absorbing at
            // the join. Must be closed (no escape past the join), must
            // not contain the sink, and must be pairwise disjoint.
            let mut union = vec![false; self.nodes.len()];
            for (bi, &t) in targets.iter().enumerate() {
                let r = super::analysis::forward_reachable(self, adj, t, Some(join));
                for (i, &in_r) in r.iter().enumerate() {
                    if i == join.0 || !in_r {
                        continue;
                    }
                    if NodeId(i) == self.sink {
                        return Err(ValidationError::ForkBranchEscapes {
                            node: n.name.clone(),
                            via: self.node(targets[bi]).name.clone(),
                        });
                    }
                    if union[i] {
                        return Err(ValidationError::OverlappingForkBranches {
                            node: n.name.clone(),
                        });
                    }
                    union[i] = true;
                }
            }
            // Region closure: every forward edge from a region node stays
            // in the region or enters the join.
            for e in &self.edges {
                if !union[e.from.0] {
                    continue;
                }
                if e.back_edge {
                    return Err(ValidationError::BackEdgeInForkRegion {
                        node: self.node(e.from).name.clone(),
                    });
                }
                if !union[e.to.0] && e.to != join {
                    return Err(ValidationError::ForkBranchEscapes {
                        node: n.name.clone(),
                        via: self.node(e.from).name.clone(),
                    });
                }
            }
            // Back edges may not jump INTO the region either.
            for e in &self.edges {
                if e.back_edge && union[e.to.0] {
                    return Err(ValidationError::BackEdgeInForkRegion {
                        node: self.node(e.to).name.clone(),
                    });
                }
            }
            matched_joins.entry(join).or_default().push(n.id);
            region_of.insert(n.id, union);
        }
        // Every annotated join must be exactly ONE fork's reconvergence
        // point (a shared join would make the barrier's branch count —
        // and the LP's inflow scale — ambiguous), and its forward
        // in-edges must all originate inside the matched fork's region.
        for n in &self.nodes {
            if n.join.is_none() {
                continue;
            }
            let Some(forks) = matched_joins.get(&n.id) else {
                return Err(ValidationError::JoinWithoutFork { node: n.name.clone() });
            };
            if forks.len() > 1 {
                return Err(ValidationError::SharedJoin { node: n.name.clone() });
            }
            for &ei in adj.in_edges(n.id) {
                let e = &self.edges[ei];
                if e.back_edge {
                    continue;
                }
                let ok = forks
                    .iter()
                    .any(|f| region_of.get(f).map(|r| r[e.from.0]).unwrap_or(false));
                if !ok {
                    return Err(ValidationError::JoinWithoutFork { node: n.name.clone() });
                }
            }
        }
        Ok(())
    }

    /// Expected visits per admitted request for every node, accounting
    /// for branch probabilities, amplification γ, recursion, and parallel
    /// dataflow. Solved by fixed-point iteration of
    /// v_j = [j==source] + Σ_i v_i γ_i w_{i,j} (converges for
    /// sub-stochastic loops, i.e. loop gain < 1). Fork edges carry full
    /// flow (w = 1 per branch — every branch is real work the allocator
    /// must provision); a join's inflow is scaled by 1/branches because
    /// the barrier merges the siblings back into one request
    /// ([`PipelineGraph::join_in_scale`]).
    pub fn visit_rates(&self) -> Vec<f64> {
        super::analysis::visit_rates_with(self, &self.join_scales())
    }

    /// Edge flow fractions per admitted request (visit rate of `from` ×
    /// γ × edge flow fraction). Used by the allocator and the DES —
    /// both read the same `super::analysis::edge_flows_from` table.
    pub fn edge_flows(&self) -> Vec<f64> {
        super::analysis::edge_flows_from(self, &self.visit_rates())
    }

    /// Per-edge *latency* weights for critical-path analysis: `Route(p)`
    /// edges keep their probability, but within each fork group exactly
    /// one branch — the one on the critical path — carries weight 1 and
    /// the siblings carry 0, because parallel branches overlap in time
    /// instead of adding. For [`JoinPolicy::All`] the critical branch is
    /// the one with the largest prior path cost (the barrier waits for
    /// the slowest); for [`JoinPolicy::FirstK`]`(k)` it is the k-th
    /// *fastest* branch (the barrier releases on the k-th arrival).
    /// `node_cost` supplies the prior mean service per node; nested forks
    /// inside a branch are costed conservatively (summed) when ranking.
    ///
    /// With these weights, the visits fixed point computes expected
    /// critical-path time instead of summed parallel work — the model
    /// behind `sched::SlackPredictor`'s remaining-time estimates and
    /// `profile::graph_latency`.
    pub fn latency_edge_weights(&self, node_cost: &HashMap<NodeId, f64>) -> Vec<f64> {
        let adj = self.adjacency();
        let fork_map = super::analysis::fork_groups_dense(self, &adj);
        super::analysis::latency_edge_weights_from(self, &fork_map, node_cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    #[test]
    fn vanilla_rag_structure() {
        let g = apps::vanilla_rag();
        g.validate().unwrap();
        assert!(!g.has_conditionals());
        assert!(!g.has_recursion());
        assert!(!g.has_forks());
        // Table 1 row: V-RAG has neither.
        let v = g.visit_rates();
        // Every node visited exactly once.
        for n in g.work_nodes() {
            assert!((v[n.id.0] - 1.0).abs() < 1e-9, "{}: {}", n.name, v[n.id.0]);
        }
    }

    #[test]
    fn corrective_rag_structure() {
        let g = apps::corrective_rag();
        g.validate().unwrap();
        assert!(g.has_conditionals());
        assert!(!g.has_recursion());
        let v = g.visit_rates();
        let web = g.node_by_name("websearch").unwrap();
        // Websearch only on the low-relevance branch.
        assert!(v[web.id.0] > 0.0 && v[web.id.0] < 1.0);
        let gen = g.node_by_name("generator").unwrap();
        assert!((v[gen.id.0] - 1.0).abs() < 1e-9, "all paths generate");
    }

    #[test]
    fn self_rag_structure() {
        let g = apps::self_rag();
        g.validate().unwrap();
        assert!(g.has_conditionals());
        assert!(g.has_recursion());
        let v = g.visit_rates();
        let retr = g.node_by_name("retriever").unwrap();
        // Recursion re-enters the retriever: expected visits > 1.
        assert!(v[retr.id.0] > 1.0, "retriever visits {}", v[retr.id.0]);
        // Sink receives exactly one completion per admitted request.
        assert!((v[g.sink.0] - 1.0).abs() < 1e-6, "sink {}", v[g.sink.0]);
    }

    #[test]
    fn adaptive_rag_structure() {
        let g = apps::adaptive_rag();
        g.validate().unwrap();
        assert!(g.has_conditionals());
        assert!(g.has_recursion());
        let v = g.visit_rates();
        assert!((v[g.sink.0] - 1.0).abs() < 1e-6, "sink {}", v[g.sink.0]);
    }

    #[test]
    fn validation_catches_bad_probabilities() {
        let mut g = apps::vanilla_rag();
        // Corrupt: make retriever's outgoing edge probability 0.5.
        let retr = g.node_by_name("retriever").unwrap().id;
        for e in g.edges.iter_mut() {
            if e.from == retr {
                e.kind = EdgeKind::Route(0.5);
            }
        }
        match g.validate() {
            Err(ValidationError::BadProbabilitySum { .. }) => {}
            other => panic!("expected BadProbabilitySum, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_unreachable() {
        let mut g = apps::vanilla_rag();
        let id = NodeId(g.nodes.len());
        g.nodes.push(NodeSpec {
            id,
            name: "orphan".into(),
            kind: ComponentKind::WebSearch,
            stateful: false,
            base_instances: 1,
            shards: 1,
            cache_hit_rate: 0.0,
            quantized: false,
            degrade: DegradeKnob::None,
            join: None,
            resources: vec![(ResourceKind::Cpu, 1.0)],
            alpha: vec![(ResourceKind::Cpu, 1.0)],
            gamma: 1.0,
            streamable: false,
        });
        // orphan needs an edge to sink for NoPathToSink not to trigger first
        g.edges.push(EdgeSpec::route(id, g.sink, 1.0));
        match g.validate() {
            Err(ValidationError::Unreachable { node }) => assert_eq!(node, "orphan"),
            other => panic!("expected Unreachable, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_zero_shards() {
        let mut g = apps::vanilla_rag();
        let retr = g.node_by_name("retriever").unwrap().id;
        g.nodes[retr.0].shards = 0;
        match g.validate() {
            Err(ValidationError::BadShards { node }) => assert_eq!(node, "retriever"),
            other => panic!("expected BadShards, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_bad_cache_hit_rate() {
        let mut g = apps::vanilla_rag();
        let retr = g.node_by_name("retriever").unwrap().id;
        g.nodes[retr.0].cache_hit_rate = 1.0; // a component cannot hit 100%
        match g.validate() {
            Err(ValidationError::BadCacheHitRate { node, .. }) => assert_eq!(node, "retriever"),
            other => panic!("expected BadCacheHitRate, got {other:?}"),
        }
        g.nodes[retr.0].cache_hit_rate = 0.85;
        g.validate().unwrap();
    }

    #[test]
    fn visit_rates_geometric_loop() {
        // source -> a -> sink with a self-loop of probability 0.5:
        // expected visits of a = 1/(1-0.5) = 2.
        let mut b = crate::spec::PipelineBuilder::new("loop-test");
        let a = b
            .component("a", ComponentKind::Generator)
            .resources(&[(ResourceKind::Gpu, 1.0)])
            .add();
        b.edge_from_source(a, 1.0);
        b.branch(a, &[]); // no forward branches; we add manually below
        let mut g = b.build_unvalidated();
        g.edges.push(EdgeSpec { from: a, to: a, kind: EdgeKind::Route(0.5), back_edge: true });
        g.edges.push(EdgeSpec::route(a, g.sink, 0.5));
        g.validate().unwrap();
        let v = g.visit_rates();
        assert!((v[a.0] - 2.0).abs() < 1e-9, "visits {}", v[a.0]);
        assert!((v[g.sink.0] - 1.0).abs() < 1e-9);
    }

    // ---- fork/join -------------------------------------------------------

    #[test]
    fn hybrid_fork_visit_rates_give_full_flow_per_branch() {
        let g = apps::hybrid_rag();
        g.validate().unwrap();
        assert!(g.has_forks());
        assert!(!g.has_conditionals(), "a fork is not a conditional");
        let v = g.visit_rates();
        // Every branch carries full flow; the join merges back to one.
        for name in ["retriever", "websearch", "generator"] {
            let id = g.node_by_name(name).unwrap().id;
            assert!((v[id.0] - 1.0).abs() < 1e-9, "{name}: {}", v[id.0]);
        }
        assert!((v[g.sink.0] - 1.0).abs() < 1e-9, "sink {}", v[g.sink.0]);
    }

    #[test]
    fn fork_groups_resolve_targets_and_join() {
        let g = apps::hybrid_rag();
        let groups = g.fork_groups();
        assert_eq!(groups.len(), 1);
        let fg = groups.values().next().unwrap();
        assert_eq!(fg.fork, g.source);
        assert_eq!(fg.join, g.node_by_name("generator").unwrap().id);
        assert_eq!(fg.targets.len(), 2);
        assert_eq!(fg.need, 2);
        assert_eq!(fg.policy, JoinPolicy::All);
    }

    #[test]
    fn adjacency_matches_linear_scans() {
        let g = apps::adaptive_rag();
        let adj = g.adjacency();
        for n in &g.nodes {
            let scan: Vec<NodeId> = g.successors(n.id).map(|e| e.to).collect();
            let idx: Vec<NodeId> =
                adj.out_edges(n.id).iter().map(|&i| g.edges[i].to).collect();
            assert_eq!(scan, idx, "{}", n.name);
            let scan_in: Vec<NodeId> = g.predecessors(n.id).map(|e| e.from).collect();
            let idx_in: Vec<NodeId> =
                adj.in_edges(n.id).iter().map(|&i| g.edges[i].from).collect();
            assert_eq!(scan_in, idx_in, "{}", n.name);
        }
    }

    /// source →fork→ {a, b} →join(c)→ sink, with knobs for breaking it.
    fn fork_fixture() -> PipelineGraph {
        let mut b = crate::spec::PipelineBuilder::new("fork-fixture");
        let a = b.component("a", ComponentKind::Retriever).add();
        let w = b.component("b", ComponentKind::WebSearch).add();
        let c = b
            .component("c", ComponentKind::Generator)
            .join(JoinSpec::all())
            .add();
        b.fork(b.source(), &[a, w]);
        b.edge(a, c, 1.0);
        b.edge(w, c, 1.0);
        b.edge_to_sink(c, 1.0);
        b.build_unvalidated()
    }

    #[test]
    fn fixture_is_valid() {
        fork_fixture().validate().unwrap();
    }

    #[test]
    fn validation_catches_unbalanced_fork() {
        // No join annotation anywhere: the branches never reconverge.
        let mut g = fork_fixture();
        let c = g.node_by_name("c").unwrap().id;
        g.nodes[c.0].join = None;
        match g.validate() {
            Err(ValidationError::UnbalancedFork { node }) => assert_eq!(node, "source"),
            other => panic!("expected UnbalancedFork, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_join_with_missing_branch() {
        // Branch `b` re-routed straight to the sink: the join never sees
        // it (and the region leaks toward the sink).
        let mut g = fork_fixture();
        let w = g.node_by_name("b").unwrap().id;
        let c = g.node_by_name("c").unwrap().id;
        for e in g.edges.iter_mut() {
            if e.from == w && e.to == c {
                e.to = g.sink;
            }
        }
        match g.validate() {
            Err(ValidationError::JoinMissingBranch { join, branch }) => {
                assert_eq!(join, "c");
                assert_eq!(branch, "b");
            }
            other => panic!("expected JoinMissingBranch, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_fork_into_back_edge() {
        let mut g = fork_fixture();
        for e in g.edges.iter_mut() {
            if e.is_fork() {
                e.back_edge = true;
                break;
            }
        }
        match g.validate() {
            Err(ValidationError::ForkIntoBackEdge { node }) => assert_eq!(node, "source"),
            other => panic!("expected ForkIntoBackEdge, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_first_k_out_of_bounds() {
        let mut g = fork_fixture();
        let c = g.node_by_name("c").unwrap().id;
        g.nodes[c.0].join = Some(JoinSpec::first_k(3)); // only 2 branches
        match g.validate() {
            Err(ValidationError::BadFirstK { node, k, branches }) => {
                assert_eq!(node, "c");
                assert_eq!(k, 3);
                assert_eq!(branches, 2);
            }
            other => panic!("expected BadFirstK, got {other:?}"),
        }
        g.nodes[c.0].join = Some(JoinSpec::first_k(0));
        assert!(matches!(g.validate(), Err(ValidationError::BadFirstK { .. })));
        g.nodes[c.0].join = Some(JoinSpec::first_k(1));
        g.validate().unwrap();
    }

    #[test]
    fn validation_catches_branch_escaping_the_region() {
        // Give branch `a` a probabilistic side exit that bypasses the
        // join toward the sink: the region is no longer closed.
        let mut g = fork_fixture();
        let a = g.node_by_name("a").unwrap().id;
        let c = g.node_by_name("c").unwrap().id;
        for e in g.edges.iter_mut() {
            if e.from == a && e.to == c {
                e.kind = EdgeKind::Route(0.5);
            }
        }
        g.edges.push(EdgeSpec::route(a, g.sink, 0.5));
        match g.validate() {
            Err(ValidationError::ForkBranchEscapes { node, .. }) => assert_eq!(node, "source"),
            other => panic!("expected ForkBranchEscapes, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_mixed_edge_kinds_and_orphan_join() {
        let mut g = fork_fixture();
        // Orphan join: annotate a node no fork resolves to.
        let a = g.node_by_name("a").unwrap().id;
        g.nodes[a.0].join = Some(JoinSpec::all());
        // `a` is now a fork target with a JoinSpec → empty branch first.
        assert!(matches!(g.validate(), Err(ValidationError::EmptyForkBranch { .. })));
        let mut g = fork_fixture();
        // Mixed kinds: add a Route edge next to the source's Fork edges.
        let a = g.node_by_name("a").unwrap().id;
        g.edges.push(EdgeSpec::route(g.source, a, 1.0));
        match g.validate() {
            Err(ValidationError::MixedEdgeKinds { node }) => assert_eq!(node, "source"),
            other => panic!("expected MixedEdgeKinds, got {other:?}"),
        }
        // Orphan join with no fork at all.
        let mut b = crate::spec::PipelineBuilder::new("orphan-join");
        let r = b.component("r", ComponentKind::Retriever).join(JoinSpec::all()).add();
        b.edge_from_source(r, 1.0);
        b.edge_to_sink(r, 1.0);
        let g = b.build_unvalidated();
        match g.validate() {
            Err(ValidationError::JoinWithoutFork { node }) => assert_eq!(node, "r"),
            other => panic!("expected JoinWithoutFork, got {other:?}"),
        }
    }

    #[test]
    fn join_scale_uses_branch_count_not_in_edge_count() {
        // Branch `a` reaches the join over TWO probabilistic edges (via
        // x or y); branch `b` over one. The join has 3 forward in-edges
        // but only 2 branches — its visit rate must still be exactly 1.
        let mut b = crate::spec::PipelineBuilder::new("multi-edge-branch");
        let a = b.component("a", ComponentKind::Retriever).add();
        let x = b.component("x", ComponentKind::Grader).add();
        let y = b.component("y", ComponentKind::Rewriter).add();
        let w = b.component("b", ComponentKind::WebSearch).add();
        let j = b
            .component("j", ComponentKind::Generator)
            .join(JoinSpec::all())
            .add();
        b.fork(b.source(), &[a, w]);
        b.branch(a, &[(x, 0.5), (y, 0.5)]);
        b.edge(x, j, 1.0);
        b.edge(y, j, 1.0);
        b.edge(w, j, 1.0);
        b.edge_to_sink(j, 1.0);
        let g = b.build().unwrap();
        assert_eq!(g.join_in_scale(j), 0.5, "scale = 1/branches, not 1/in-edges");
        let v = g.visit_rates();
        assert!((v[j.0] - 1.0).abs() < 1e-9, "join visits {}", v[j.0]);
        assert!((v[g.sink.0] - 1.0).abs() < 1e-9, "sink visits {}", v[g.sink.0]);
        assert!((v[x.0] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn validation_rejects_a_join_shared_by_two_forks() {
        // Two forks reconverging on one join node: the barrier's branch
        // count would be ambiguous.
        let mut b = crate::spec::PipelineBuilder::new("shared-join");
        let a = b.component("a", ComponentKind::Retriever).add();
        let c = b.component("c", ComponentKind::WebSearch).add();
        let f2 = b.component("f2", ComponentKind::Classifier).add();
        let d = b.component("d", ComponentKind::Grader).add();
        let e = b.component("e", ComponentKind::Rewriter).add();
        let j = b
            .component("j", ComponentKind::Generator)
            .join(JoinSpec::all())
            .add();
        b.fork(b.source(), &[a, c]);
        b.edge(a, j, 1.0);
        b.edge(c, f2, 1.0);
        b.fork(f2, &[d, e]);
        b.edge(d, j, 1.0);
        b.edge(e, j, 1.0);
        b.edge_to_sink(j, 1.0);
        let g = b.build_unvalidated();
        match g.validate() {
            Err(ValidationError::SharedJoin { node }) => assert_eq!(node, "j"),
            other => panic!("expected SharedJoin, got {other:?}"),
        }
    }

    #[test]
    fn latency_weights_pick_the_critical_branch() {
        let g = apps::hybrid_rag();
        // Priors: websearch much slower than the retriever.
        let mut cost: HashMap<NodeId, f64> = HashMap::new();
        for n in &g.nodes {
            cost.insert(n.id, 0.0);
        }
        let retr = g.node_by_name("retriever").unwrap().id;
        let web = g.node_by_name("websearch").unwrap().id;
        cost.insert(retr, 0.1);
        cost.insert(web, 0.15);
        let w = g.latency_edge_weights(&cost);
        let (wi, _) = g.edges.iter().enumerate().find(|(_, e)| e.to == web).unwrap();
        let (ri, _) = g.edges.iter().enumerate().find(|(_, e)| e.to == retr).unwrap();
        assert_eq!(w[wi], 1.0, "slow branch is the critical path");
        assert_eq!(w[ri], 0.0, "fast branch overlaps under the slow one");
        // Flip the costs: the critical branch flips with them.
        cost.insert(retr, 0.3);
        let w = g.latency_edge_weights(&cost);
        assert_eq!(w[wi], 0.0);
        assert_eq!(w[ri], 1.0);
    }
}
