//! Pipeline graph representation: the machine-readable control-flow
//! structure the deployment and runtime layers reason over.
//!
//! Mirrors the paper's model (§3.2): nodes are components with
//! per-resource throughput coefficients α_{i,k} and amplification factors
//! γ_i; edges carry routing probabilities p_{i,j}. Back edges (recursion)
//! are first-class and folded into effective visit rates for the
//! allocation LP.

use std::collections::HashMap;

/// Resource types K in the allocation model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// CPU cores.
    Cpu,
    /// Whole GPUs.
    Gpu,
    /// RAM in GiB.
    Ram,
}

impl ResourceKind {
    pub const ALL: [ResourceKind; 3] = [ResourceKind::Cpu, ResourceKind::Gpu, ResourceKind::Ram];

    pub fn name(self) -> &'static str {
        match self {
            ResourceKind::Cpu => "CPU",
            ResourceKind::Gpu => "GPU",
            ResourceKind::Ram => "RAM",
        }
    }
}

/// What a component *is* — used to pick live executors and default latency
/// models. New kinds integrate without framework changes via `Custom`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ComponentKind {
    /// Pipeline entry (admission); zero-cost.
    Source,
    /// Pipeline exit (response); zero-cost.
    Sink,
    /// Vector retrieval (CPU/memory-bound).
    Retriever,
    /// LLM generation (GPU-bound, prefill+decode).
    Generator,
    /// LLM-based relevance grader (GPU, single output token).
    Grader,
    /// LLM-based output critic (GPU, single output token).
    Critic,
    /// LLM-based query rewriter (GPU, short generation).
    Rewriter,
    /// External web search (I/O bound).
    WebSearch,
    /// Query complexity classifier (small model).
    Classifier,
    /// User-defined component with a latency profile supplied at
    /// registration — the "library-agnostic integration" hook.
    Custom(String),
}

impl ComponentKind {
    pub fn name(&self) -> &str {
        match self {
            ComponentKind::Source => "source",
            ComponentKind::Sink => "sink",
            ComponentKind::Retriever => "retriever",
            ComponentKind::Generator => "generator",
            ComponentKind::Grader => "grader",
            ComponentKind::Critic => "critic",
            ComponentKind::Rewriter => "rewriter",
            ComponentKind::WebSearch => "websearch",
            ComponentKind::Classifier => "classifier",
            ComponentKind::Custom(s) => s,
        }
    }

    /// Does this component run on the GPU-style resource?
    pub fn gpu_bound(&self) -> bool {
        matches!(
            self,
            ComponentKind::Generator
                | ComponentKind::Grader
                | ComponentKind::Critic
                | ComponentKind::Rewriter
                | ComponentKind::Classifier
        )
    }
}

/// Node handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Which overload-degradation knob this component exposes, if any
/// (declared per node, like `stateful` or `shards`; acted on only when
/// the runtime's `sched::DegradePolicy` is enabled and the cluster is
/// overloaded — the default control plane never degrades).
///
/// Each knob trades a small quality delta for a large latency win under
/// burst load (RAGO-style per-stage degradation):
///
/// * [`DegradeKnob::ShrinkTopK`] — retrieval-style stages fetch fewer
///   documents (top-k shrinks with the overload level).
/// * [`DegradeKnob::SkipHop`] — optional quality hops (reranker, grader)
///   are bypassed entirely at severe overload; the pipeline takes the
///   success branch as if the hop had passed.
/// * [`DegradeKnob::CapIterations`] — recursive refinement loops
///   (critic → rewrite) exit after the current pass at severe overload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DegradeKnob {
    /// Never degraded (the default for every component).
    #[default]
    None,
    /// Shrink retrieval top-k under overload.
    ShrinkTopK,
    /// Skip this optional quality hop at severe overload.
    SkipHop,
    /// Stop re-entering the refinement loop at severe overload.
    CapIterations,
}

/// One pipeline component plus its declarative constraints (§3.1
/// "Specifying workflow constraints").
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub id: NodeId,
    pub name: String,
    pub kind: ComponentKind,
    /// Recursive invocations must return to the same instance.
    pub stateful: bool,
    /// Minimum instances kept warm (cold-start protection).
    pub base_instances: usize,
    /// Index shards for partitioned components (retrieval scatter-gather):
    /// each request fans out to all `shards` partitions in parallel, each
    /// holding ~1/shards of the data. 1 = unsharded. The allocator sizes
    /// each shard's replica pool independently.
    pub shards: usize,
    /// Expected request-cache hit rate for this component (retrieval
    /// memoization): fraction of visits served from the query cache at a
    /// small fixed cost instead of a full pass. 0 = uncached. Set from
    /// the workload skew via `profile::models::zipf_hit_rate`; applied by
    /// the profiler and the DES through
    /// `profile::models::cache_service_factor`, so the LP priors and the
    /// autoscaler see cache-adjusted α.
    pub cache_hit_rate: f64,
    /// Overload-degradation knob (see [`DegradeKnob`]); `None` for
    /// components that must always run at full fidelity.
    pub degrade: DegradeKnob,
    /// Per-instance resource demand (r constraint granularity).
    pub resources: Vec<(ResourceKind, f64)>,
    /// Throughput coefficient α_{i,k}: requests/sec per unit of resource k
    /// (profiled; these are the deploy-time priors).
    pub alpha: Vec<(ResourceKind, f64)>,
    /// Request amplification γ_i (>1 fan-out, <1 abridgement).
    pub gamma: f64,
    /// Whether the component can stream output to its successor.
    pub streamable: bool,
}

impl NodeSpec {
    pub fn alpha_for(&self, k: ResourceKind) -> f64 {
        self.alpha
            .iter()
            .find(|(rk, _)| *rk == k)
            .map(|(_, a)| *a)
            .unwrap_or(0.0)
    }

    pub fn demand_for(&self, k: ResourceKind) -> f64 {
        self.resources
            .iter()
            .find(|(rk, _)| *rk == k)
            .map(|(_, d)| *d)
            .unwrap_or(0.0)
    }
}

/// Directed edge with routing probability p_{i,j}; `back_edge` marks
/// recursion (loops back toward an ancestor in the DAG backbone).
#[derive(Clone, Debug)]
pub struct EdgeSpec {
    pub from: NodeId,
    pub to: NodeId,
    pub prob: f64,
    pub back_edge: bool,
}

/// The captured pipeline graph.
#[derive(Clone, Debug)]
pub struct PipelineGraph {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
    pub edges: Vec<EdgeSpec>,
    pub source: NodeId,
    pub sink: NodeId,
}

#[derive(Debug, PartialEq)]
pub enum ValidationError {
    BadProbabilitySum { node: String, sum: f64 },
    Unreachable { node: String },
    NoPathToSink { node: String },
    BadGamma { node: String, gamma: f64 },
    BadShards { node: String },
    BadCacheHitRate { node: String, rate: f64 },
    SelfLoopWithoutBackEdge { node: String },
    DuplicateName(String),
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValidationError::BadProbabilitySum { node, sum } => {
                write!(f, "outgoing probabilities of '{node}' sum to {sum}, expected 1")
            }
            ValidationError::Unreachable { node } => write!(f, "'{node}' unreachable from source"),
            ValidationError::NoPathToSink { node } => write!(f, "'{node}' has no path to sink"),
            ValidationError::BadGamma { node, gamma } => {
                write!(f, "'{node}' has non-positive gamma {gamma}")
            }
            ValidationError::BadShards { node } => {
                write!(f, "'{node}' has zero shards (must be >= 1)")
            }
            ValidationError::BadCacheHitRate { node, rate } => {
                write!(f, "'{node}' has cache hit rate {rate} outside [0, 1)")
            }
            ValidationError::SelfLoopWithoutBackEdge { node } => {
                write!(f, "'{node}' has a self loop not marked as back edge")
            }
            ValidationError::DuplicateName(n) => write!(f, "duplicate component name '{n}'"),
        }
    }
}

impl PipelineGraph {
    pub fn node(&self, id: NodeId) -> &NodeSpec {
        &self.nodes[id.0]
    }

    pub fn node_by_name(&self, name: &str) -> Option<&NodeSpec> {
        self.nodes.iter().find(|n| n.name == name)
    }

    pub fn successors(&self, id: NodeId) -> impl Iterator<Item = &EdgeSpec> {
        self.edges.iter().filter(move |e| e.from == id)
    }

    pub fn predecessors(&self, id: NodeId) -> impl Iterator<Item = &EdgeSpec> {
        self.edges.iter().filter(move |e| e.to == id)
    }

    /// Components that do real work (not source/sink).
    pub fn work_nodes(&self) -> impl Iterator<Item = &NodeSpec> {
        self.nodes
            .iter()
            .filter(|n| !matches!(n.kind, ComponentKind::Source | ComponentKind::Sink))
    }

    /// Does the workflow contain conditional branching (Table 1)?
    pub fn has_conditionals(&self) -> bool {
        let mut out: HashMap<NodeId, usize> = HashMap::new();
        for e in &self.edges {
            *out.entry(e.from).or_insert(0) += 1;
        }
        out.values().any(|&c| c > 1)
    }

    /// Does the workflow contain recursion (Table 1)?
    pub fn has_recursion(&self) -> bool {
        self.edges.iter().any(|e| e.back_edge)
    }

    /// Structural validation; run by the builder and unit tests.
    pub fn validate(&self) -> Result<(), ValidationError> {
        // Unique names.
        let mut seen = std::collections::HashSet::new();
        for n in &self.nodes {
            if !seen.insert(n.name.clone()) {
                return Err(ValidationError::DuplicateName(n.name.clone()));
            }
            if n.gamma <= 0.0 {
                return Err(ValidationError::BadGamma { node: n.name.clone(), gamma: n.gamma });
            }
            if n.shards == 0 {
                return Err(ValidationError::BadShards { node: n.name.clone() });
            }
            if !(0.0..1.0).contains(&n.cache_hit_rate) {
                return Err(ValidationError::BadCacheHitRate {
                    node: n.name.clone(),
                    rate: n.cache_hit_rate,
                });
            }
        }
        // Probability sums.
        for n in &self.nodes {
            let succ: Vec<_> = self.successors(n.id).collect();
            if n.id == self.sink {
                continue;
            }
            if !succ.is_empty() {
                let sum: f64 = succ.iter().map(|e| e.prob).sum();
                if (sum - 1.0).abs() > 1e-6 {
                    return Err(ValidationError::BadProbabilitySum { node: n.name.clone(), sum });
                }
            }
        }
        for e in &self.edges {
            if e.from == e.to && !e.back_edge {
                return Err(ValidationError::SelfLoopWithoutBackEdge {
                    node: self.node(e.from).name.clone(),
                });
            }
        }
        // Reachability from source (forward edges and back edges both count).
        let mut reach = vec![false; self.nodes.len()];
        let mut stack = vec![self.source];
        reach[self.source.0] = true;
        while let Some(u) = stack.pop() {
            for e in self.successors(u) {
                if !reach[e.to.0] {
                    reach[e.to.0] = true;
                    stack.push(e.to);
                }
            }
        }
        for n in &self.nodes {
            if !reach[n.id.0] {
                return Err(ValidationError::Unreachable { node: n.name.clone() });
            }
        }
        // Path to sink.
        let mut to_sink = vec![false; self.nodes.len()];
        to_sink[self.sink.0] = true;
        let mut changed = true;
        while changed {
            changed = false;
            for e in &self.edges {
                if to_sink[e.to.0] && !to_sink[e.from.0] {
                    to_sink[e.from.0] = true;
                    changed = true;
                }
            }
        }
        for n in &self.nodes {
            if !to_sink[n.id.0] {
                return Err(ValidationError::NoPathToSink { node: n.name.clone() });
            }
        }
        Ok(())
    }

    /// Expected visits per admitted request for every node, accounting for
    /// branch probabilities, amplification γ, and recursion. Solved by
    /// fixed-point iteration of v_j = [j==source] + Σ_i v_i γ_i p_{i,j}
    /// (converges for sub-stochastic loops, i.e. loop gain < 1).
    pub fn visit_rates(&self) -> Vec<f64> {
        let n = self.nodes.len();
        let mut v = vec![0.0f64; n];
        v[self.source.0] = 1.0;
        for _ in 0..10_000 {
            let mut nv = vec![0.0f64; n];
            nv[self.source.0] = 1.0;
            for e in &self.edges {
                nv[e.to.0] += v[e.from.0] * self.node(e.from).gamma * e.prob;
            }
            let diff: f64 = nv.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
            v = nv;
            if diff < 1e-12 {
                break;
            }
        }
        v
    }

    /// Edge flow fractions per admitted request (visit rate of `from` ×
    /// γ × p). Used by the allocator and the DES.
    pub fn edge_flows(&self) -> Vec<f64> {
        let v = self.visit_rates();
        self.edges
            .iter()
            .map(|e| v[e.from.0] * self.node(e.from).gamma * e.prob)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::apps;

    #[test]
    fn vanilla_rag_structure() {
        let g = apps::vanilla_rag();
        g.validate().unwrap();
        assert!(!g.has_conditionals());
        assert!(!g.has_recursion());
        // Table 1 row: V-RAG has neither.
        let v = g.visit_rates();
        // Every node visited exactly once.
        for n in g.work_nodes() {
            assert!((v[n.id.0] - 1.0).abs() < 1e-9, "{}: {}", n.name, v[n.id.0]);
        }
    }

    #[test]
    fn corrective_rag_structure() {
        let g = apps::corrective_rag();
        g.validate().unwrap();
        assert!(g.has_conditionals());
        assert!(!g.has_recursion());
        let v = g.visit_rates();
        let web = g.node_by_name("websearch").unwrap();
        // Websearch only on the low-relevance branch.
        assert!(v[web.id.0] > 0.0 && v[web.id.0] < 1.0);
        let gen = g.node_by_name("generator").unwrap();
        assert!((v[gen.id.0] - 1.0).abs() < 1e-9, "all paths generate");
    }

    #[test]
    fn self_rag_structure() {
        let g = apps::self_rag();
        g.validate().unwrap();
        assert!(g.has_conditionals());
        assert!(g.has_recursion());
        let v = g.visit_rates();
        let retr = g.node_by_name("retriever").unwrap();
        // Recursion re-enters the retriever: expected visits > 1.
        assert!(v[retr.id.0] > 1.0, "retriever visits {}", v[retr.id.0]);
        // Sink receives exactly one completion per admitted request.
        assert!((v[g.sink.0] - 1.0).abs() < 1e-6, "sink {}", v[g.sink.0]);
    }

    #[test]
    fn adaptive_rag_structure() {
        let g = apps::adaptive_rag();
        g.validate().unwrap();
        assert!(g.has_conditionals());
        assert!(g.has_recursion());
        let v = g.visit_rates();
        assert!((v[g.sink.0] - 1.0).abs() < 1e-6, "sink {}", v[g.sink.0]);
    }

    #[test]
    fn validation_catches_bad_probabilities() {
        let mut g = apps::vanilla_rag();
        // Corrupt: make retriever's outgoing edge probability 0.5.
        let retr = g.node_by_name("retriever").unwrap().id;
        for e in g.edges.iter_mut() {
            if e.from == retr {
                e.prob = 0.5;
            }
        }
        match g.validate() {
            Err(ValidationError::BadProbabilitySum { .. }) => {}
            other => panic!("expected BadProbabilitySum, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_unreachable() {
        let mut g = apps::vanilla_rag();
        let id = NodeId(g.nodes.len());
        g.nodes.push(NodeSpec {
            id,
            name: "orphan".into(),
            kind: ComponentKind::WebSearch,
            stateful: false,
            base_instances: 1,
            shards: 1,
            cache_hit_rate: 0.0,
            degrade: DegradeKnob::None,
            resources: vec![(ResourceKind::Cpu, 1.0)],
            alpha: vec![(ResourceKind::Cpu, 1.0)],
            gamma: 1.0,
            streamable: false,
        });
        // orphan needs an edge to sink for NoPathToSink not to trigger first
        g.edges.push(EdgeSpec { from: id, to: g.sink, prob: 1.0, back_edge: false });
        match g.validate() {
            Err(ValidationError::Unreachable { node }) => assert_eq!(node, "orphan"),
            other => panic!("expected Unreachable, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_zero_shards() {
        let mut g = apps::vanilla_rag();
        let retr = g.node_by_name("retriever").unwrap().id;
        g.nodes[retr.0].shards = 0;
        match g.validate() {
            Err(ValidationError::BadShards { node }) => assert_eq!(node, "retriever"),
            other => panic!("expected BadShards, got {other:?}"),
        }
    }

    #[test]
    fn validation_catches_bad_cache_hit_rate() {
        let mut g = apps::vanilla_rag();
        let retr = g.node_by_name("retriever").unwrap().id;
        g.nodes[retr.0].cache_hit_rate = 1.0; // a component cannot hit 100%
        match g.validate() {
            Err(ValidationError::BadCacheHitRate { node, .. }) => assert_eq!(node, "retriever"),
            other => panic!("expected BadCacheHitRate, got {other:?}"),
        }
        g.nodes[retr.0].cache_hit_rate = 0.85;
        g.validate().unwrap();
    }

    #[test]
    fn visit_rates_geometric_loop() {
        // source -> a -> sink with a self-loop of probability 0.5:
        // expected visits of a = 1/(1-0.5) = 2.
        let mut b = crate::spec::PipelineBuilder::new("loop-test");
        let a = b
            .component("a", ComponentKind::Generator)
            .resources(&[(ResourceKind::Gpu, 1.0)])
            .add();
        b.edge_from_source(a, 1.0);
        b.branch(a, &[]); // no forward branches; we add manually below
        let mut g = b.build_unvalidated();
        g.edges.push(EdgeSpec { from: a, to: a, prob: 0.5, back_edge: true });
        g.edges.push(EdgeSpec { from: a, to: g.sink, prob: 0.5, back_edge: false });
        g.validate().unwrap();
        let v = g.visit_rates();
        assert!((v[a.0] - 2.0).abs() < 1e-9, "visits {}", v[a.0]);
        assert!((v[g.sink.0] - 1.0).abs() < 1e-9);
    }
}
