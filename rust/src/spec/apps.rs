//! The four representative RAG applications of the paper (Table 1), plus
//! the parallel-dataflow extensions (hybrid retrieval and multi-query
//! expansion — Modular RAG's branching/fusion patterns).
//!
//! | App        | Conditional | Recursive | Parallel |
//! |------------|-------------|-----------|----------|
//! | V-RAG      | no          | no        | no       |
//! | C-RAG      | yes         | no        | no       |
//! | S-RAG      | yes         | yes       | no       |
//! | A-RAG      | yes         | yes       | no       |
//! | Hybrid-RAG | no          | no        | yes      |
//! | MQ-RAG     | no          | no        | yes      |
//!
//! Branch probabilities are the *deploy-time priors* (the paper estimates
//! them by profiling ~100 ShareGPT samples; the runtime layer re-estimates
//! them online). Resource demands follow §4.3's allocation-plan discussion
//! (retrievers: 8 CPU + 112 GiB RAM; LLM components: 1 GPU).

use super::builder::PipelineBuilder;
use super::graph::{ComponentKind, DegradeKnob, JoinSpec, PipelineGraph, ResourceKind};

const RETRIEVER_RES: [(ResourceKind, f64); 2] =
    [(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)];
const GPU_RES: [(ResourceKind, f64); 1] = [(ResourceKind::Gpu, 1.0)];
const WEB_RES: [(ResourceKind, f64); 1] = [(ResourceKind::Cpu, 1.0)];

/// C-RAG prior: fraction of queries whose retrieved documents are graded
/// relevant (skip web search).
pub const CRAG_P_RELEVANT: f64 = 0.7;
/// S-RAG prior: probability the critic accepts the generation (exit loop).
pub const SRAG_P_ACCEPT: f64 = 0.65;
/// A-RAG priors: query-complexity class mix (simple / standard / complex).
pub const ARAG_P_SIMPLE: f64 = 0.2;
pub const ARAG_P_STANDARD: f64 = 0.5;
pub const ARAG_P_COMPLEX: f64 = 0.3;
/// A-RAG prior: probability the iterative loop continues another round.
pub const ARAG_P_LOOP: f64 = 0.5;

/// Vanilla RAG: retrieve → generate. No conditionals, no recursion.
pub fn vanilla_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("v-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("v-rag is valid")
}

/// Vanilla RAG with a sharded retriever: the corpus is partitioned into
/// `n_shards` independent IVF shards; every query scatter-gathers across
/// one replica of each shard. Per-replica resources describe one shard
/// replica of the *modeled distributed deployment* and shrink with the
/// shard count (such a replica holds ~1/n of the corpus, so its RAM
/// footprint divides) — the independent-scaling lever the paper
/// attributes to retrieval: the allocator can add capacity in
/// shard-replica quanta instead of whole-corpus quanta. (The in-process
/// live path approximates this: workers share one `Arc<ShardedIndex>`,
/// so process memory holds a single corpus copy regardless of replica
/// count; the simulator charges a complete replica set `n` bundles.)
pub fn sharded_vanilla_rag(n_shards: usize) -> PipelineGraph {
    let n_shards = n_shards.max(1);
    let mut b = PipelineBuilder::new("v-rag-sharded");
    let shard_res = [
        (ResourceKind::Cpu, 8.0),
        (ResourceKind::Ram, (112.0 / n_shards as f64).max(1.0)),
    ];
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&shard_res)
        .shards(n_shards)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("v-rag-sharded is valid")
}

/// Vanilla RAG with a request cache in front of retrieval: a Zipfian
/// repeat-query workload (`QueryMix { zipf_s, repeat_frac }` over a pool
/// of `query_pool` distinct queries) against a cache of `cache_entries`
/// entries yields the steady-state hit rate
/// `profile::models::zipf_hit_rate`, recorded on the retriever as
/// `NodeSpec::cache_hit_rate`. The profiler and DES shrink that fraction
/// of retrievals to the cache-hit cost, so the allocation LP sizes the
/// retrieval pool for the *miss* traffic only — the first component
/// whose effective capacity grows with load skew.
pub fn cached_vanilla_rag(
    zipf_s: f64,
    repeat_frac: f64,
    cache_entries: usize,
    query_pool: usize,
) -> PipelineGraph {
    let hit = crate::profile::models::zipf_hit_rate(zipf_s, repeat_frac, query_pool, cache_entries)
        .min(0.99);
    let mut b = PipelineBuilder::new("v-rag-cached");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .cache_hit_rate(hit)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("v-rag-cached is valid")
}

/// Hybrid RAG (dense ∥ keyword/web retrieval): the entry forks into a
/// vector retriever AND a web search running **in parallel**; the
/// generator is the barrier ([`JoinSpec::all`]) that fuses both contexts
/// (doc-id union with dedup) before decoding. The serialized equivalent
/// ([`hybrid_rag_sequential`]) runs the same two stages back to back, so
/// the fork saves `min(retriever, websearch)` of critical-path latency
/// per request at identical resource demand — the RAGO-style overlap win.
pub fn hybrid_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("hybrid-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .add();
    let web = b
        .component("websearch", ComponentKind::WebSearch)
        .resources(&WEB_RES)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .join(JoinSpec::all())
        .streamable(true)
        .add();
    b.fork(b.source(), &[retr, web]);
    b.edge(retr, gen, 1.0);
    b.edge(web, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("hybrid-rag is valid")
}

/// The serialized control for [`hybrid_rag`]: identical nodes and
/// resources, but dense retrieval and web search chained end to end.
/// `benches/fig07_parallel_dataflow.rs` pits the two against each other
/// at equal allocation.
///
/// Generated mechanically from [`hybrid_rag`] by the
/// [`super::passes::Sequentialize`] rewrite pass (and pinned
/// bit-identical to the retired hand-written construction in this
/// module's tests) — every forked app gets its equal-allocation control
/// for free.
pub fn hybrid_rag_sequential() -> PipelineGraph {
    use super::passes::Pass;
    let g = super::passes::Sequentialize
        .apply(&hybrid_rag())
        .expect("hybrid-rag has exactly one fork region");
    g.validate().expect("hybrid-rag-seq is valid");
    g
}

/// Multi-query RAG (query expansion): a rewriter fans out into `n`
/// parallel branches, each rewriting one query variant and retrieving
/// with it; the generator joins all branches ([`JoinSpec::all`]) on the
/// fused, deduplicated context. Every branch carries full flow through
/// the allocator — expansion multiplies retrieval *work*, but the fork
/// keeps it off the *critical path* (one variant's latency, not `n`).
pub fn multiquery_rag(n: usize) -> PipelineGraph {
    let n = n.clamp(2, 8);
    let mut b = PipelineBuilder::new("mq-rag");
    let mut entries = Vec::with_capacity(n);
    let mut retrs = Vec::with_capacity(n);
    for i in 0..n {
        let rw = b
            .component(&format!("rewriter_q{i}"), ComponentKind::Rewriter)
            .resources(&GPU_RES)
            .add();
        let r = b
            .component(&format!("retriever_q{i}"), ComponentKind::Retriever)
            .resources(&RETRIEVER_RES)
            .degrade(DegradeKnob::ShrinkTopK)
            .add();
        b.edge(rw, r, 1.0);
        entries.push(rw);
        retrs.push(r);
    }
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .join(JoinSpec::all())
        .streamable(true)
        .add();
    b.fork(b.source(), &entries);
    for r in retrs {
        b.edge(r, gen, 1.0);
    }
    b.edge_to_sink(gen, 1.0);
    b.build().expect("mq-rag is valid")
}

/// The serialized control for [`multiquery_rag`]: the same `n`
/// rewrite→retrieve pairs chained end to end before the generator.
///
/// Generated mechanically from [`multiquery_rag`] by the
/// [`super::passes::Sequentialize`] rewrite pass (and pinned
/// bit-identical to the retired hand-written construction in this
/// module's tests).
pub fn multiquery_rag_sequential(n: usize) -> PipelineGraph {
    use super::passes::Pass;
    let g = super::passes::Sequentialize
        .apply(&multiquery_rag(n))
        .expect("mq-rag has exactly one fork region");
    g.validate().expect("mq-rag-seq is valid");
    g
}

/// Corrective RAG [Yan et al.]: retrieve → grade → {generate | rewrite →
/// web search → generate}. Purely conditional control flow.
pub fn corrective_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("c-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let grader = b
        .component("grader", ComponentKind::Grader)
        .resources(&GPU_RES)
        .base_instances(2) // Fig. 7: @harmonia.make(base_instances=2)
        .stateful(true)
        .degrade(DegradeKnob::SkipHop)
        .add();
    let rewriter = b
        .component("rewriter", ComponentKind::Rewriter)
        .resources(&GPU_RES)
        .add();
    let web = b
        .component("websearch", ComponentKind::WebSearch)
        .resources(&WEB_RES)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, grader, 1.0);
    b.branch(grader, &[(gen, CRAG_P_RELEVANT), (rewriter, 1.0 - CRAG_P_RELEVANT)]);
    b.edge(rewriter, web, 1.0);
    b.edge(web, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("c-rag is valid")
}

/// Self-RAG [Asai et al.]: retrieve → generate → critic → {done | rewrite
/// and re-retrieve}. Conditional + recursive.
pub fn self_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("s-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .stateful(true) // per-request generation state across iterations
        .add();
    let critic = b
        .component("critic", ComponentKind::Critic)
        .resources(&GPU_RES)
        .degrade(DegradeKnob::CapIterations)
        .add();
    let rewriter = b
        .component("rewriter", ComponentKind::Rewriter)
        .resources(&GPU_RES)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge(gen, critic, 1.0);
    b.branch(critic, &[(b.sink(), SRAG_P_ACCEPT), (rewriter, 1.0 - SRAG_P_ACCEPT)]);
    b.recurse(rewriter, retr, 1.0);
    b.build().expect("s-rag is valid")
}

/// Adaptive RAG [Jeong et al.]: classify → {LLM-only | single-pass RAG |
/// iterative multi-step RAG}. Conditional + recursive subgraph.
pub fn adaptive_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("a-rag");
    let cls = b
        .component("classifier", ComponentKind::Classifier)
        .resources(&GPU_RES)
        .add();
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    // Iterative branch: its own retrieve→generate→critic loop over a
    // subgraph (multi-step RAG for complex queries).
    let iretr = b
        .component("iter_retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .add();
    let igen = b
        .component("iter_generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .stateful(true) // iteration state must return to the same instance
        .add();
    let icritic = b
        .component("iter_critic", ComponentKind::Critic)
        .resources(&GPU_RES)
        .degrade(DegradeKnob::CapIterations)
        .add();

    b.edge_from_source(cls, 1.0);
    b.branch(
        cls,
        &[(gen, ARAG_P_SIMPLE), (retr, ARAG_P_STANDARD), (iretr, ARAG_P_COMPLEX)],
    );
    // Standard path.
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    // Iterative path.
    b.edge(iretr, igen, 1.0);
    b.edge(igen, icritic, 1.0);
    b.branch(icritic, &[(b.sink(), 1.0 - ARAG_P_LOOP)]);
    b.recurse(icritic, iretr, ARAG_P_LOOP);
    b.build().expect("a-rag is valid")
}

/// All four apps, in the paper's presentation order.
pub fn all() -> Vec<PipelineGraph> {
    vec![vanilla_rag(), corrective_rag(), self_rag(), adaptive_rag()]
}

/// Look up an app by its short name (v-rag, c-rag, s-rag, a-rag, plus
/// the sharded-retrieval variant v-rag-sharded, the request-cache
/// variant v-rag-cached, and the parallel-dataflow apps hybrid-rag /
/// mq-rag with their serialized `-seq` controls).
pub fn by_name(name: &str) -> Option<PipelineGraph> {
    match name {
        "v-rag" => Some(vanilla_rag()),
        "v-rag-sharded" => Some(sharded_vanilla_rag(4)),
        "v-rag-cached" => Some(cached_vanilla_rag(1.1, 0.7, 1024, 4096)),
        "c-rag" => Some(corrective_rag()),
        "s-rag" => Some(self_rag()),
        "a-rag" => Some(adaptive_rag()),
        "hybrid-rag" => Some(hybrid_rag()),
        "hybrid-rag-seq" => Some(hybrid_rag_sequential()),
        "mq-rag" => Some(multiquery_rag(3)),
        "mq-rag-seq" => Some(multiquery_rag_sequential(3)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_structure_matrix() {
        let cases = [
            ("v-rag", false, false),
            ("c-rag", true, false),
            ("s-rag", true, true),
            ("a-rag", true, true),
        ];
        for (name, cond, rec) in cases {
            let g = by_name(name).unwrap();
            assert_eq!(g.has_conditionals(), cond, "{name} conditional");
            assert_eq!(g.has_recursion(), rec, "{name} recursive");
        }
    }

    #[test]
    fn all_apps_validate() {
        for g in all() {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn srag_expected_iterations() {
        // Geometric loop: expected pipeline iterations = 1 / p_accept.
        let g = self_rag();
        let v = g.visit_rates();
        let gen = g.node_by_name("generator").unwrap();
        let expected = 1.0 / SRAG_P_ACCEPT;
        assert!(
            (v[gen.id.0] - expected).abs() < 1e-6,
            "generator visits {} vs {}",
            v[gen.id.0],
            expected
        );
    }

    #[test]
    fn arag_classifier_sees_every_request() {
        let g = adaptive_rag();
        let v = g.visit_rates();
        let cls = g.node_by_name("classifier").unwrap();
        assert!((v[cls.id.0] - 1.0).abs() < 1e-9);
        // Main generator serves simple + standard paths only.
        let gen = g.node_by_name("generator").unwrap();
        assert!((v[gen.id.0] - (ARAG_P_SIMPLE + ARAG_P_STANDARD)).abs() < 1e-9);
        // Iterative retriever: p_complex / (1 - p_loop).
        let iretr = g.node_by_name("iter_retriever").unwrap();
        let expected = ARAG_P_COMPLEX / (1.0 - ARAG_P_LOOP);
        assert!((v[iretr.id.0] - expected).abs() < 1e-6, "{}", v[iretr.id.0]);
    }

    #[test]
    fn sharded_vrag_mirrors_vrag_structure() {
        let g = sharded_vanilla_rag(4);
        g.validate().unwrap();
        assert!(!g.has_conditionals());
        assert!(!g.has_recursion());
        let retr = g.node_by_name("retriever").unwrap();
        assert_eq!(retr.shards, 4);
        // Per-replica RAM shrinks with the shard count.
        let full = vanilla_rag();
        let full_ram = full.node_by_name("retriever").unwrap().demand_for(ResourceKind::Ram);
        assert!(retr.demand_for(ResourceKind::Ram) < full_ram / 2.0);
        // Degenerate case: 1 shard is plain v-rag resourcing.
        let g1 = sharded_vanilla_rag(1);
        assert_eq!(g1.node_by_name("retriever").unwrap().shards, 1);
    }

    #[test]
    fn cached_vrag_records_skew_derived_hit_rate() {
        let g = cached_vanilla_rag(1.2, 0.8, 1024, 4096);
        g.validate().unwrap();
        let retr = g.node_by_name("retriever").unwrap();
        assert!((0.0..1.0).contains(&retr.cache_hit_rate));
        assert!(retr.cache_hit_rate > 0.3, "hit {}", retr.cache_hit_rate);
        // More skew → higher recorded hit rate.
        let flat = cached_vanilla_rag(0.3, 0.8, 1024, 4096);
        assert!(flat.node_by_name("retriever").unwrap().cache_hit_rate < retr.cache_hit_rate);
        // No repeats → no hits → plain v-rag economics.
        let cold = cached_vanilla_rag(1.2, 0.0, 1024, 4096);
        assert_eq!(cold.node_by_name("retriever").unwrap().cache_hit_rate, 0.0);
        assert!(by_name("v-rag-cached").is_some());
    }

    #[test]
    fn degrade_knobs_annotated() {
        // Every retrieval stage can shrink top-k; C-RAG's grader is an
        // optional quality hop; the recursive critics cap their loops.
        // Generators are never degraded — answers must always be produced.
        let v = vanilla_rag();
        assert_eq!(v.node_by_name("retriever").unwrap().degrade, DegradeKnob::ShrinkTopK);
        assert_eq!(v.node_by_name("generator").unwrap().degrade, DegradeKnob::None);
        let c = corrective_rag();
        assert_eq!(c.node_by_name("grader").unwrap().degrade, DegradeKnob::SkipHop);
        let s = self_rag();
        assert_eq!(s.node_by_name("critic").unwrap().degrade, DegradeKnob::CapIterations);
        let a = adaptive_rag();
        assert_eq!(
            a.node_by_name("iter_critic").unwrap().degrade,
            DegradeKnob::CapIterations
        );
    }

    #[test]
    fn parallel_apps_validate_and_fork() {
        for name in ["hybrid-rag", "mq-rag"] {
            let g = by_name(name).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.has_forks(), "{name} must contain fork edges");
            assert!(!g.has_conditionals(), "{name} forks are not conditionals");
            assert!(!g.has_recursion(), "{name}");
        }
        for name in ["hybrid-rag-seq", "mq-rag-seq"] {
            let g = by_name(name).unwrap();
            g.validate().unwrap();
            assert!(!g.has_forks(), "{name} is the serialized control");
        }
    }

    #[test]
    fn pre_existing_apps_have_zero_fork_edges() {
        // Acceptance criterion: the legacy apps are untouched by the
        // fork/join refactor — no Fork edges, no join annotations.
        for name in ["v-rag", "v-rag-sharded", "v-rag-cached", "c-rag", "s-rag", "a-rag"] {
            let g = by_name(name).unwrap();
            assert!(!g.has_forks(), "{name} grew a fork edge");
            assert!(g.nodes.iter().all(|n| n.join.is_none()), "{name} grew a join");
        }
    }

    #[test]
    fn serialized_controls_mirror_the_parallel_apps() {
        // Equal resources: the fig07 comparison is latency-shape only.
        let (p, s) = (hybrid_rag(), hybrid_rag_sequential());
        assert_eq!(p.work_nodes().count(), s.work_nodes().count());
        for n in p.work_nodes() {
            let m = s.node_by_name(&n.name).expect("same node set");
            assert_eq!(n.resources, m.resources, "{}", n.name);
        }
        let (p, s) = (multiquery_rag(3), multiquery_rag_sequential(3));
        assert_eq!(p.work_nodes().count(), s.work_nodes().count());
        // Visit rates: every branch carries full flow in BOTH shapes —
        // the fork buys latency overlap, not less work.
        let vp = p.visit_rates();
        let vs = s.visit_rates();
        for n in p.work_nodes() {
            let m = s.node_by_name(&n.name).unwrap();
            assert!(
                (vp[n.id.0] - vs[m.id.0]).abs() < 1e-9,
                "{}: parallel {} vs serial {}",
                n.name,
                vp[n.id.0],
                vs[m.id.0]
            );
        }
    }

    #[test]
    fn multiquery_branch_count_is_clamped() {
        assert_eq!(multiquery_rag(1).fork_groups()[&multiquery_rag(1).source].targets.len(), 2);
        let g = multiquery_rag(3);
        let fg = &g.fork_groups()[&g.source];
        assert_eq!(fg.targets.len(), 3);
        assert_eq!(fg.need, 3);
    }

    #[test]
    fn stateful_constraints_present() {
        let g = self_rag();
        assert!(g.node_by_name("generator").unwrap().stateful);
        let g = corrective_rag();
        assert!(g.node_by_name("grader").unwrap().stateful);
        assert_eq!(g.node_by_name("grader").unwrap().base_instances, 2);
    }

    /// The retired hand-written construction of `hybrid-rag-seq`, kept
    /// only as the bit-identity oracle for the `Sequentialize` pass.
    fn hand_written_hybrid_rag_sequential() -> PipelineGraph {
        let mut b = PipelineBuilder::new("hybrid-rag-seq");
        let retr = b
            .component("retriever", ComponentKind::Retriever)
            .resources(&RETRIEVER_RES)
            .degrade(DegradeKnob::ShrinkTopK)
            .add();
        let web = b
            .component("websearch", ComponentKind::WebSearch)
            .resources(&WEB_RES)
            .add();
        let gen = b
            .component("generator", ComponentKind::Generator)
            .resources(&GPU_RES)
            .streamable(true)
            .add();
        b.edge_from_source(retr, 1.0);
        b.edge(retr, web, 1.0);
        b.edge(web, gen, 1.0);
        b.edge_to_sink(gen, 1.0);
        b.build().expect("hybrid-rag-seq is valid")
    }

    /// The retired hand-written construction of `mq-rag-seq` (oracle).
    fn hand_written_multiquery_rag_sequential(n: usize) -> PipelineGraph {
        let n = n.clamp(2, 8);
        let mut b = PipelineBuilder::new("mq-rag-seq");
        let mut prev: Option<super::super::graph::NodeId> = None;
        for i in 0..n {
            let rw = b
                .component(&format!("rewriter_q{i}"), ComponentKind::Rewriter)
                .resources(&GPU_RES)
                .add();
            let r = b
                .component(&format!("retriever_q{i}"), ComponentKind::Retriever)
                .resources(&RETRIEVER_RES)
                .degrade(DegradeKnob::ShrinkTopK)
                .add();
            match prev {
                None => {
                    b.edge_from_source(rw, 1.0);
                }
                Some(p) => {
                    b.edge(p, rw, 1.0);
                }
            }
            b.edge(rw, r, 1.0);
            prev = Some(r);
        }
        let gen = b
            .component("generator", ComponentKind::Generator)
            .resources(&GPU_RES)
            .streamable(true)
            .add();
        b.edge(prev.expect("n >= 2"), gen, 1.0);
        b.edge_to_sink(gen, 1.0);
        b.build().expect("mq-rag-seq is valid")
    }

    #[test]
    fn generated_sequential_controls_are_bit_identical_to_the_hand_written_apps() {
        // Acceptance criterion: auto-generated `*_sequential` controls
        // reproduce the retired hand-written constructions exactly —
        // same nodes, same fields, same edge declaration order.
        assert_eq!(
            format!("{:?}", hybrid_rag_sequential()),
            format!("{:?}", hand_written_hybrid_rag_sequential())
        );
        for n in [2, 3, 5] {
            assert_eq!(
                format!("{:?}", multiquery_rag_sequential(n)),
                format!("{:?}", hand_written_multiquery_rag_sequential(n)),
                "mq-rag-seq with {n} branches"
            );
        }
    }
}
