//! The four representative RAG applications of the paper (Table 1).
//!
//! | App   | Conditional | Recursive |
//! |-------|-------------|-----------|
//! | V-RAG | no          | no        |
//! | C-RAG | yes         | no        |
//! | S-RAG | yes         | yes       |
//! | A-RAG | yes         | yes       |
//!
//! Branch probabilities are the *deploy-time priors* (the paper estimates
//! them by profiling ~100 ShareGPT samples; the runtime layer re-estimates
//! them online). Resource demands follow §4.3's allocation-plan discussion
//! (retrievers: 8 CPU + 112 GiB RAM; LLM components: 1 GPU).

use super::builder::PipelineBuilder;
use super::graph::{ComponentKind, DegradeKnob, PipelineGraph, ResourceKind};

const RETRIEVER_RES: [(ResourceKind, f64); 2] =
    [(ResourceKind::Cpu, 8.0), (ResourceKind::Ram, 112.0)];
const GPU_RES: [(ResourceKind, f64); 1] = [(ResourceKind::Gpu, 1.0)];
const WEB_RES: [(ResourceKind, f64); 1] = [(ResourceKind::Cpu, 1.0)];

/// C-RAG prior: fraction of queries whose retrieved documents are graded
/// relevant (skip web search).
pub const CRAG_P_RELEVANT: f64 = 0.7;
/// S-RAG prior: probability the critic accepts the generation (exit loop).
pub const SRAG_P_ACCEPT: f64 = 0.65;
/// A-RAG priors: query-complexity class mix (simple / standard / complex).
pub const ARAG_P_SIMPLE: f64 = 0.2;
pub const ARAG_P_STANDARD: f64 = 0.5;
pub const ARAG_P_COMPLEX: f64 = 0.3;
/// A-RAG prior: probability the iterative loop continues another round.
pub const ARAG_P_LOOP: f64 = 0.5;

/// Vanilla RAG: retrieve → generate. No conditionals, no recursion.
pub fn vanilla_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("v-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("v-rag is valid")
}

/// Vanilla RAG with a sharded retriever: the corpus is partitioned into
/// `n_shards` independent IVF shards; every query scatter-gathers across
/// one replica of each shard. Per-replica resources describe one shard
/// replica of the *modeled distributed deployment* and shrink with the
/// shard count (such a replica holds ~1/n of the corpus, so its RAM
/// footprint divides) — the independent-scaling lever the paper
/// attributes to retrieval: the allocator can add capacity in
/// shard-replica quanta instead of whole-corpus quanta. (The in-process
/// live path approximates this: workers share one `Arc<ShardedIndex>`,
/// so process memory holds a single corpus copy regardless of replica
/// count; the simulator charges a complete replica set `n` bundles.)
pub fn sharded_vanilla_rag(n_shards: usize) -> PipelineGraph {
    let n_shards = n_shards.max(1);
    let mut b = PipelineBuilder::new("v-rag-sharded");
    let shard_res = [
        (ResourceKind::Cpu, 8.0),
        (ResourceKind::Ram, (112.0 / n_shards as f64).max(1.0)),
    ];
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&shard_res)
        .shards(n_shards)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("v-rag-sharded is valid")
}

/// Vanilla RAG with a request cache in front of retrieval: a Zipfian
/// repeat-query workload (`QueryMix { zipf_s, repeat_frac }` over a pool
/// of `query_pool` distinct queries) against a cache of `cache_entries`
/// entries yields the steady-state hit rate
/// `profile::models::zipf_hit_rate`, recorded on the retriever as
/// `NodeSpec::cache_hit_rate`. The profiler and DES shrink that fraction
/// of retrievals to the cache-hit cost, so the allocation LP sizes the
/// retrieval pool for the *miss* traffic only — the first component
/// whose effective capacity grows with load skew.
pub fn cached_vanilla_rag(
    zipf_s: f64,
    repeat_frac: f64,
    cache_entries: usize,
    query_pool: usize,
) -> PipelineGraph {
    let hit = crate::profile::models::zipf_hit_rate(zipf_s, repeat_frac, query_pool, cache_entries)
        .min(0.99);
    let mut b = PipelineBuilder::new("v-rag-cached");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .cache_hit_rate(hit)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("v-rag-cached is valid")
}

/// Corrective RAG [Yan et al.]: retrieve → grade → {generate | rewrite →
/// web search → generate}. Purely conditional control flow.
pub fn corrective_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("c-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let grader = b
        .component("grader", ComponentKind::Grader)
        .resources(&GPU_RES)
        .base_instances(2) // Fig. 7: @harmonia.make(base_instances=2)
        .stateful(true)
        .degrade(DegradeKnob::SkipHop)
        .add();
    let rewriter = b
        .component("rewriter", ComponentKind::Rewriter)
        .resources(&GPU_RES)
        .add();
    let web = b
        .component("websearch", ComponentKind::WebSearch)
        .resources(&WEB_RES)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, grader, 1.0);
    b.branch(grader, &[(gen, CRAG_P_RELEVANT), (rewriter, 1.0 - CRAG_P_RELEVANT)]);
    b.edge(rewriter, web, 1.0);
    b.edge(web, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    b.build().expect("c-rag is valid")
}

/// Self-RAG [Asai et al.]: retrieve → generate → critic → {done | rewrite
/// and re-retrieve}. Conditional + recursive.
pub fn self_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("s-rag");
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .stateful(true) // per-request generation state across iterations
        .add();
    let critic = b
        .component("critic", ComponentKind::Critic)
        .resources(&GPU_RES)
        .degrade(DegradeKnob::CapIterations)
        .add();
    let rewriter = b
        .component("rewriter", ComponentKind::Rewriter)
        .resources(&GPU_RES)
        .add();
    b.edge_from_source(retr, 1.0);
    b.edge(retr, gen, 1.0);
    b.edge(gen, critic, 1.0);
    b.branch(critic, &[(b.sink(), SRAG_P_ACCEPT), (rewriter, 1.0 - SRAG_P_ACCEPT)]);
    b.recurse(rewriter, retr, 1.0);
    b.build().expect("s-rag is valid")
}

/// Adaptive RAG [Jeong et al.]: classify → {LLM-only | single-pass RAG |
/// iterative multi-step RAG}. Conditional + recursive subgraph.
pub fn adaptive_rag() -> PipelineGraph {
    let mut b = PipelineBuilder::new("a-rag");
    let cls = b
        .component("classifier", ComponentKind::Classifier)
        .resources(&GPU_RES)
        .add();
    let retr = b
        .component("retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .streamable(true)
        .add();
    let gen = b
        .component("generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .streamable(true)
        .add();
    // Iterative branch: its own retrieve→generate→critic loop over a
    // subgraph (multi-step RAG for complex queries).
    let iretr = b
        .component("iter_retriever", ComponentKind::Retriever)
        .resources(&RETRIEVER_RES)
        .degrade(DegradeKnob::ShrinkTopK)
        .add();
    let igen = b
        .component("iter_generator", ComponentKind::Generator)
        .resources(&GPU_RES)
        .stateful(true) // iteration state must return to the same instance
        .add();
    let icritic = b
        .component("iter_critic", ComponentKind::Critic)
        .resources(&GPU_RES)
        .degrade(DegradeKnob::CapIterations)
        .add();

    b.edge_from_source(cls, 1.0);
    b.branch(
        cls,
        &[(gen, ARAG_P_SIMPLE), (retr, ARAG_P_STANDARD), (iretr, ARAG_P_COMPLEX)],
    );
    // Standard path.
    b.edge(retr, gen, 1.0);
    b.edge_to_sink(gen, 1.0);
    // Iterative path.
    b.edge(iretr, igen, 1.0);
    b.edge(igen, icritic, 1.0);
    b.branch(icritic, &[(b.sink(), 1.0 - ARAG_P_LOOP)]);
    b.recurse(icritic, iretr, ARAG_P_LOOP);
    b.build().expect("a-rag is valid")
}

/// All four apps, in the paper's presentation order.
pub fn all() -> Vec<PipelineGraph> {
    vec![vanilla_rag(), corrective_rag(), self_rag(), adaptive_rag()]
}

/// Look up an app by its short name (v-rag, c-rag, s-rag, a-rag, plus
/// the sharded-retrieval variant v-rag-sharded and the request-cache
/// variant v-rag-cached).
pub fn by_name(name: &str) -> Option<PipelineGraph> {
    match name {
        "v-rag" => Some(vanilla_rag()),
        "v-rag-sharded" => Some(sharded_vanilla_rag(4)),
        "v-rag-cached" => Some(cached_vanilla_rag(1.1, 0.7, 1024, 4096)),
        "c-rag" => Some(corrective_rag()),
        "s-rag" => Some(self_rag()),
        "a-rag" => Some(adaptive_rag()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_structure_matrix() {
        let cases = [
            ("v-rag", false, false),
            ("c-rag", true, false),
            ("s-rag", true, true),
            ("a-rag", true, true),
        ];
        for (name, cond, rec) in cases {
            let g = by_name(name).unwrap();
            assert_eq!(g.has_conditionals(), cond, "{name} conditional");
            assert_eq!(g.has_recursion(), rec, "{name} recursive");
        }
    }

    #[test]
    fn all_apps_validate() {
        for g in all() {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn srag_expected_iterations() {
        // Geometric loop: expected pipeline iterations = 1 / p_accept.
        let g = self_rag();
        let v = g.visit_rates();
        let gen = g.node_by_name("generator").unwrap();
        let expected = 1.0 / SRAG_P_ACCEPT;
        assert!(
            (v[gen.id.0] - expected).abs() < 1e-6,
            "generator visits {} vs {}",
            v[gen.id.0],
            expected
        );
    }

    #[test]
    fn arag_classifier_sees_every_request() {
        let g = adaptive_rag();
        let v = g.visit_rates();
        let cls = g.node_by_name("classifier").unwrap();
        assert!((v[cls.id.0] - 1.0).abs() < 1e-9);
        // Main generator serves simple + standard paths only.
        let gen = g.node_by_name("generator").unwrap();
        assert!((v[gen.id.0] - (ARAG_P_SIMPLE + ARAG_P_STANDARD)).abs() < 1e-9);
        // Iterative retriever: p_complex / (1 - p_loop).
        let iretr = g.node_by_name("iter_retriever").unwrap();
        let expected = ARAG_P_COMPLEX / (1.0 - ARAG_P_LOOP);
        assert!((v[iretr.id.0] - expected).abs() < 1e-6, "{}", v[iretr.id.0]);
    }

    #[test]
    fn sharded_vrag_mirrors_vrag_structure() {
        let g = sharded_vanilla_rag(4);
        g.validate().unwrap();
        assert!(!g.has_conditionals());
        assert!(!g.has_recursion());
        let retr = g.node_by_name("retriever").unwrap();
        assert_eq!(retr.shards, 4);
        // Per-replica RAM shrinks with the shard count.
        let full = vanilla_rag();
        let full_ram = full.node_by_name("retriever").unwrap().demand_for(ResourceKind::Ram);
        assert!(retr.demand_for(ResourceKind::Ram) < full_ram / 2.0);
        // Degenerate case: 1 shard is plain v-rag resourcing.
        let g1 = sharded_vanilla_rag(1);
        assert_eq!(g1.node_by_name("retriever").unwrap().shards, 1);
    }

    #[test]
    fn cached_vrag_records_skew_derived_hit_rate() {
        let g = cached_vanilla_rag(1.2, 0.8, 1024, 4096);
        g.validate().unwrap();
        let retr = g.node_by_name("retriever").unwrap();
        assert!((0.0..1.0).contains(&retr.cache_hit_rate));
        assert!(retr.cache_hit_rate > 0.3, "hit {}", retr.cache_hit_rate);
        // More skew → higher recorded hit rate.
        let flat = cached_vanilla_rag(0.3, 0.8, 1024, 4096);
        assert!(flat.node_by_name("retriever").unwrap().cache_hit_rate < retr.cache_hit_rate);
        // No repeats → no hits → plain v-rag economics.
        let cold = cached_vanilla_rag(1.2, 0.0, 1024, 4096);
        assert_eq!(cold.node_by_name("retriever").unwrap().cache_hit_rate, 0.0);
        assert!(by_name("v-rag-cached").is_some());
    }

    #[test]
    fn degrade_knobs_annotated() {
        // Every retrieval stage can shrink top-k; C-RAG's grader is an
        // optional quality hop; the recursive critics cap their loops.
        // Generators are never degraded — answers must always be produced.
        let v = vanilla_rag();
        assert_eq!(v.node_by_name("retriever").unwrap().degrade, DegradeKnob::ShrinkTopK);
        assert_eq!(v.node_by_name("generator").unwrap().degrade, DegradeKnob::None);
        let c = corrective_rag();
        assert_eq!(c.node_by_name("grader").unwrap().degrade, DegradeKnob::SkipHop);
        let s = self_rag();
        assert_eq!(s.node_by_name("critic").unwrap().degrade, DegradeKnob::CapIterations);
        let a = adaptive_rag();
        assert_eq!(
            a.node_by_name("iter_critic").unwrap().degrade,
            DegradeKnob::CapIterations
        );
    }

    #[test]
    fn stateful_constraints_present() {
        let g = self_rag();
        assert!(g.node_by_name("generator").unwrap().stateful);
        let g = corrective_rag();
        assert!(g.node_by_name("grader").unwrap().stateful);
        assert_eq!(g.node_by_name("grader").unwrap().base_instances, 2);
    }
}
