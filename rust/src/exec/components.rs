//! Live stage implementations: the paper's serving-ready component
//! classes (Retriever / Generator / Grader / Critic / Rewriter /
//! WebSearch / Classifier), backed by real XLA artifacts and the IVF
//! store. Each is a [`StageLogic`] built inside its worker thread.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{chain_of, CacheConfig, KvCacheConfig, KvPrefixCache, QueryCache};
use crate::metrics::SchedCounters;
use crate::profile::models::{DecodeCostModel, KV_PREFIX_HIT_COST_FRAC};
use crate::retrieval::{IvfParams, SearchResult, ShardParams, ShardedIndex};
use crate::runtime::classifier::Classifier;
use crate::runtime::embedder::Embedder;
use crate::runtime::generator::{GenRequest, Generator, InflightBatch};
use crate::sched::degrade::{degraded_top_k, OverloadCell, OverloadLevel};
use crate::spec::graph::{ComponentKind, DegradeKnob};
use crate::workload::Corpus;

use super::messages::WorkItem;
use super::worker::{spawn_worker, StageLogic, StepDone, SteppedStage, WorkerHandle};

/// Which execution engine backs the live workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineMode {
    /// Real XLA artifacts (embedder / generator / classifier loaded from
    /// `LiveShared::artifacts`).
    #[default]
    Artifacts,
    /// Artifact-free deterministic echo engine: hash-based embeddings
    /// over the SAME IVF index / caches / scatter-gather path, and
    /// pure-function generator / verdict / rewriter / classifier stages
    /// ([`echo_answer`] et al.). Used by `benches/perf_live.rs` and the
    /// artifact-free regression tests — it exercises the entire
    /// controller / router / worker / retrieval hot path without XLA.
    Echo,
}

/// Shared read-only deployment state handed to every worker.
pub struct LiveShared {
    /// Engine backing the workers (XLA artifacts vs the echo engine).
    pub engine: EngineMode,
    pub corpus: Arc<Corpus>,
    /// Sharded IVF index: retrieval scatter-gathers across corpus shards
    /// (see `retrieval::sharded`).
    pub index: Arc<ShardedIndex>,
    /// Request cache memoizing the embed→retrieve prefix (None = every
    /// query pays the full scatter-gather; see `cache::QueryCache`).
    pub cache: Option<Arc<QueryCache>>,
    /// KV prefix cache over retrieved-context segment chains (None =
    /// every prefill attends the full context; see `cache::kv_prefix`).
    /// Generator workers probe it before prefill and memoize the chain
    /// after; hits discount the prefill share of service attribution by
    /// `KV_PREFIX_HIT_COST_FRAC` scaled to the covered bytes. Shared
    /// across generator instances so a repeat hits regardless of which
    /// replica prefilled the original.
    pub kv_cache: Option<Arc<KvPrefixCache>>,
    /// Shared overload level published by the controller's control-plane
    /// tick; workers with a degrade knob poll it on their hot path
    /// (`Normal` forever unless `sched::DegradePolicy` is enabled).
    pub degrade: Arc<OverloadCell>,
    /// Overload-control counters shared with the controller's plane
    /// (workers report degraded visits here).
    pub sched_counters: Arc<SchedCounters>,
    /// Epoch for the cache's explicit clock (TTL accounting).
    pub epoch: Instant,
    pub artifacts: PathBuf,
    /// Top-k passages to retrieve per query (live scale).
    pub k_docs: usize,
    /// IVF candidate bound (the Fig. 4 knob).
    pub search_ef: usize,
    /// Generation budget per request.
    pub max_new_tokens: usize,
    /// Bytes of each passage included in the context.
    pub ctx_bytes_per_doc: usize,
    /// Max rewrite iterations before forcing exit (termination bound).
    pub max_iterations: u32,
    /// Iteration-level (continuous) batching for the generator stage:
    /// requests join a free decode slot between steps and retire at EOS,
    /// instead of run-to-completion batches (`ControllerConfig`'s
    /// `continuous_batching` knob; defaults on for the live path).
    pub continuous_batching: bool,
}

impl StageLogic for Box<dyn StageLogic> {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        (**self).process_batch(items)
    }
    fn max_batch(&self) -> usize {
        (**self).max_batch()
    }
    fn stepped(&mut self) -> Option<&mut dyn SteppedStage> {
        (**self).stepped()
    }
}

// ---------------------------------------------------------------------------

/// Query embedder behind the retriever: either the real XLA artifact or
/// the deterministic hash embedding ([`Corpus::hash_embed`]) the echo
/// engine shares with the pure-Rust sim path. Both feed the same IVF
/// index and caches, so the echo retriever is the real retriever.
enum AnyEmbedder {
    Xla(Embedder),
    Echo,
}

/// Embedding dimension for [`EngineMode::Echo`] (index build + queries).
const ECHO_EMBED_DIM: usize = 64;

impl AnyEmbedder {
    fn batch(&self) -> usize {
        match self {
            AnyEmbedder::Xla(e) => e.batch(),
            AnyEmbedder::Echo => 8,
        }
    }

    fn embed_batch(&self, texts: &[&[u8]]) -> Result<Vec<Vec<f32>>> {
        match self {
            AnyEmbedder::Xla(e) => e.embed_batch(texts),
            AnyEmbedder::Echo => {
                Ok(texts.iter().map(|t| Corpus::hash_embed(t, ECHO_EMBED_DIM)).collect())
            }
        }
    }
}

/// Scatter-gather retriever with a request cache in front: each query
/// first probes the cache's exact tier (normalized text), misses are
/// embedded in one artifact call, probe the semantic tier with that
/// embedding, and only the residual misses pay the scatter-gather across
/// the index shards (one scoped thread per shard, per
/// `retrieval::sharded`); fresh results repopulate both tiers. Each
/// worker instance of this logic is one scatter-gather replica; the
/// router spreads requests across replicas while the replica spreads
/// each request across shards (the cache is shared across replicas, so a
/// repeat hits no matter which replica served the original).
struct RetrieverLogic {
    embedder: AnyEmbedder,
    shared: Arc<LiveShared>,
    /// Degrade knob from the node spec (`ShrinkTopK` on retrieval
    /// stages): under overload the scatter-gather fetches fewer docs.
    knob: DegradeKnob,
}

/// Assemble the retrieval output (context bytes + doc ids) from a top-k
/// hit list — shared by the cached and uncached paths, so a cache hit is
/// bit-identical to recomputing the same hits.
fn fill_from_hits(
    shared: &LiveShared,
    state: &mut crate::exec::messages::RagState,
    hits: &[SearchResult],
) {
    let mut ctx = Vec::new();
    let mut ids = Vec::new();
    let mut segs = Vec::new();
    for h in hits {
        ids.push(h.id);
        let p = &shared.corpus.passages[h.id];
        let take = p.text.len().min(shared.ctx_bytes_per_doc);
        let before = ctx.len();
        ctx.extend_from_slice(&p.text[..take]);
        ctx.push(b' ');
        // Per-doc segment boundary: lets a join barrier union branch
        // contexts with per-document dedup (`RagState::merge`).
        segs.push(ctx.len() - before);
    }
    state.set_context(ctx, ids, segs);
}

impl StageLogic for RetrieverLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        for chunk in items.chunks_mut(self.embedder.batch()) {
            let now = self.shared.epoch.elapsed().as_secs_f64();
            // Tier 1: exact-match probe on normalized query text.
            let mut miss_idx: Vec<usize> = Vec::new();
            for (i, it) in chunk.iter_mut().enumerate() {
                let hit = self
                    .shared
                    .cache
                    .as_ref()
                    .and_then(|c| c.lookup_exact(it.state.query(), now));
                match hit {
                    Some(hits) => fill_from_hits(&self.shared, &mut it.state, &hits),
                    None => miss_idx.push(i),
                }
            }
            if miss_idx.is_empty() {
                continue;
            }
            // Embed the misses in one artifact call.
            let texts: Vec<&[u8]> =
                miss_idx.iter().map(|&i| chunk[i].state.query()).collect();
            let embs = self.embedder.embed_batch(&texts)?;
            // Tier 2: semantic probe with the just-computed embeddings.
            let mut search_idx: Vec<usize> = Vec::new(); // indexes into miss_idx
            for (mi, emb) in embs.iter().enumerate() {
                let hit = self
                    .shared
                    .cache
                    .as_ref()
                    .and_then(|c| c.lookup_semantic(emb, now));
                match hit {
                    Some(hits) => {
                        fill_from_hits(&self.shared, &mut chunk[miss_idx[mi]].state, &hits)
                    }
                    None => search_idx.push(mi),
                }
            }
            if search_idx.is_empty() {
                continue;
            }
            // Dedup residual misses by normalized query text: intra-chunk
            // repeats of a hot query (the common case under Zipf skew)
            // fan out once and share the result. Sharing results across
            // normalization variants is the exact tier's documented
            // semantics, so this only runs when the cache is enabled —
            // with cache: None every query retrieves with its own
            // embedding, exactly like the pre-cache code path.
            let mut uniq: Vec<usize> = Vec::new(); // representative mi per key
            let mut rep_of: Vec<usize> = Vec::with_capacity(search_idx.len());
            if self.shared.cache.is_some() {
                let mut seen: std::collections::HashMap<Vec<u8>, usize> =
                    std::collections::HashMap::new();
                for &mi in &search_idx {
                    let key =
                        crate::cache::normalize_query(chunk[miss_idx[mi]].state.query());
                    let next = uniq.len();
                    let slot = *seen.entry(key).or_insert(next);
                    if slot == next {
                        uniq.push(mi);
                    }
                    rep_of.push(slot);
                }
            } else {
                uniq.extend_from_slice(&search_idx);
                rep_of.extend(0..search_idx.len());
            }
            // Overload degradation (ShrinkTopK): fetch fewer docs while
            // the shared cell reports overload. Degraded results are NOT
            // written to the cache — a post-overload repeat must get the
            // full-fidelity pass, not a memoized degraded one. Counted
            // per request served degraded (one per residual miss), the
            // same unit the DES and VerdictLogic use.
            let level = self.shared.degrade.level();
            let k = degraded_top_k(self.shared.k_docs, self.knob, level);
            if k < self.shared.k_docs {
                self.shared.sched_counters.on_degraded_n(search_idx.len() as u64);
            }
            // Scatter across shards, gather merged top-k, repopulate the
            // cache. When every query missed and is distinct (always the
            // case with the cache disabled) the embeddings pass straight
            // through — no per-query clone on the uncached hot path.
            let all_hits = if uniq.len() == embs.len() {
                self.shared.index.search_batch(&embs, k, self.shared.search_ef)
            } else {
                let residual: Vec<Vec<f32>> = uniq.iter().map(|&mi| embs[mi].clone()).collect();
                self.shared.index.search_batch(&residual, k, self.shared.search_ef)
            };
            for (j, &mi) in search_idx.iter().enumerate() {
                let hits = &all_hits[rep_of[j]];
                let it = &mut chunk[miss_idx[mi]];
                // One cache write per distinct key (the representative),
                // full-fidelity results only.
                match self.shared.cache.as_ref() {
                    Some(c) if uniq[rep_of[j]] == mi && k == self.shared.k_docs => {
                        c.insert(it.state.query(), &embs[mi], hits, now)
                    }
                    _ => {}
                }
                fill_from_hits(&self.shared, &mut it.state, hits);
            }
        }
        Ok(())
    }

    fn max_batch(&self) -> usize {
        8
    }
}

// ---------------------------------------------------------------------------

/// The LLM stage. Two execution modes:
///
/// * **Static fallback** (`continuous_batching: false`) — the worker's
///   run-to-completion batch loop calls `process_batch`; per-item service
///   attribution is weighted by each slot's prefill + decode cost instead
///   of the uniform `elapsed / batch.len()` split that skewed telemetry
///   α-calibration.
/// * **Continuous** (the default) — the worker runs the stepped loop:
///   [`SteppedStage::admit`] prefills into a free [`InflightBatch`] slot,
///   [`SteppedStage::step`] decodes one iteration and retires EOS/capped
///   requests, and tokens stream into the in-flight item's answer per
///   step.
struct GeneratorLogic {
    generator: Generator,
    shared: Arc<LiveShared>,
    /// Continuous-batching state (lazily created on first admission).
    inflight: Option<InflightBatch>,
    /// Per-slot in-flight items, parallel to the batch slots.
    items: Vec<Option<PendingGen>>,
}

struct PendingGen {
    item: WorkItem,
    queue_secs: f64,
}

/// Probe the KV prefix cache for this request's retrieved-context chain
/// and memoize it. Returns the prefill *attribution* factor: 1.0 on a
/// miss (or with no cache), shrinking toward `KV_PREFIX_HIT_COST_FRAC`
/// as the cached prefix covers more of the context bytes. The engine
/// still recomputes the prefill — restoring KV state inside the XLA
/// engine is future work — so the factor adjusts the service-weight
/// split (what a reuse-capable engine would charge this slot), while the
/// DES's modeled twin (`SimConfig::kv_prefix_hit_rate`) carries the
/// latency effect end-to-end. Hit/miss counters surface in
/// `RunReport::kv_prefix`.
fn kv_probe(shared: &LiveShared, state: &crate::exec::messages::RagState) -> f64 {
    let Some(kc) = shared.kv_cache.as_ref() else { return 1.0 };
    let now = shared.epoch.elapsed().as_secs_f64();
    let chain = chain_of(state.doc_ids(), state.ctx_segments());
    let hit = kc.lookup(&chain, now);
    kc.insert(&chain, now);
    match hit {
        Some(h) if !state.context_is_empty() => {
            let frac = (h.bytes as f64 / state.context_len() as f64).min(1.0);
            1.0 - frac * (1.0 - KV_PREFIX_HIT_COST_FRAC)
        }
        _ => 1.0,
    }
}

fn build_prompt(state: &crate::exec::messages::RagState, max_len: usize) -> Vec<u8> {
    let mut p = Vec::with_capacity(max_len);
    p.extend_from_slice(b"C:");
    for part in state.context_parts() {
        p.extend_from_slice(part);
    }
    p.extend_from_slice(b" Q:");
    p.extend_from_slice(state.query());
    p.extend_from_slice(b" A:");
    p.truncate(max_len);
    p
}

impl StageLogic for GeneratorLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        let budget = self.generator.max_seq() / 2;
        let dcm = DecodeCostModel::generator();
        for chunk in items.chunks_mut(self.generator.max_batch()) {
            let reqs: Vec<GenRequest> = chunk
                .iter()
                .map(|i| GenRequest::greedy(&build_prompt(&i.state, budget), self.shared.max_new_tokens))
                .collect();
            let (results, _timing) = self.generator.generate_batch(&reqs, |_, _| {})?;
            let b = chunk.len();
            for (it, r) in chunk.iter_mut().zip(results) {
                // Per-slot attribution weight: this slot's prefill plus
                // its own decode steps — not the batch-max the engine ran
                // for. The worker splits the measured batch time by these.
                // A KV prefix hit discounts the prefill share (the part a
                // reuse-capable engine would have restored from cache).
                let kv = kv_probe(&self.shared, &it.state);
                it.service_weight =
                    kv * dcm.prefill(r.prompt_tokens) + r.generated_tokens as f64 * dcm.step(b);
                it.state.set_answer(r.output);
            }
        }
        Ok(())
    }

    fn max_batch(&self) -> usize {
        8
    }

    fn stepped(&mut self) -> Option<&mut dyn SteppedStage> {
        if self.shared.continuous_batching {
            Some(self)
        } else {
            None
        }
    }
}

impl SteppedStage for GeneratorLogic {
    fn occupancy(&self) -> usize {
        self.inflight.as_ref().map_or(0, |b| b.occupancy())
    }

    fn free_slots(&self) -> usize {
        self.inflight
            .as_ref()
            .map_or_else(|| self.generator.max_batch(), |b| b.free_slots())
    }

    fn admit(&mut self, mut item: WorkItem) -> Vec<StepDone> {
        // A drained, poisoned batch is replaced wholesale: the next
        // admission starts from fresh KV state.
        if self
            .inflight
            .as_ref()
            .is_some_and(|b| b.poisoned().is_some() && b.occupancy() == 0)
        {
            self.inflight = None;
        }
        let batch = self
            .inflight
            .get_or_insert_with(|| self.generator.begin_inflight());
        if self.items.is_empty() {
            self.items = (0..batch.bucket()).map(|_| None).collect();
        }
        let queue_secs = item.enqueued_at.elapsed().as_secs_f64();
        let budget = self.generator.max_seq() / 2;
        // Probe the shared KV prefix cache before prefill (admission IS
        // the prefill stage of the stepped split); the chain is memoized
        // once the prefill lands in a slot. Continuous mode attributes
        // measured per-slot seconds at retirement, so the probe here
        // feeds the reuse counters rather than a weight.
        let kv_chain = self.shared.kv_cache.as_ref().map(|kc| {
            let now = self.shared.epoch.elapsed().as_secs_f64();
            let chain = chain_of(item.state.doc_ids(), item.state.ctx_segments());
            kc.lookup(&chain, now);
            chain
        });
        let req = GenRequest::greedy(
            &build_prompt(&item.state, budget),
            self.shared.max_new_tokens,
        );
        // Tokens stream into the answer as steps decode; start clean.
        item.state.clear_answer();
        match self.generator.inflight_admit(batch, &req) {
            Ok(slot) => {
                if let (Some(kc), Some(chain)) = (self.shared.kv_cache.as_ref(), kv_chain) {
                    kc.insert(&chain, self.shared.epoch.elapsed().as_secs_f64());
                }
                self.items[slot] = Some(PendingGen { item, queue_secs });
                Vec::new()
            }
            // Prefill failure is item-local: the request retires with its
            // own error and co-resident requests keep decoding.
            Err(e) => vec![StepDone {
                item,
                service_secs: 0.0,
                queue_secs,
                error: Some(format!("prefill-on-join failed: {e:#}")),
            }],
        }
    }

    fn step(&mut self) -> Result<Vec<StepDone>> {
        let GeneratorLogic { generator, inflight, items, .. } = self;
        let Some(batch) = inflight.as_mut() else { return Ok(Vec::new()) };
        let retired = generator.inflight_step(batch, &mut |slot, byte| {
            // Streaming: each accepted token lands in the in-flight
            // item's answer the step it decodes.
            if let Some(p) = items[slot].as_mut() {
                p.item.state.answer_mut().push(byte);
            }
        })?;
        Ok(retired
            .into_iter()
            .filter_map(|d| {
                let p = items[d.slot].take()?;
                let PendingGen { mut item, queue_secs } = p;
                item.state.set_answer(d.result.output);
                Some(StepDone {
                    item,
                    service_secs: d.service_secs,
                    queue_secs,
                    error: None,
                })
            })
            .collect())
    }

    fn drain(&mut self) -> Vec<WorkItem> {
        // Poisoned after a step error: drop the KV state entirely; the
        // next admission starts a fresh batch.
        if let Some(b) = self.inflight.as_mut() {
            b.clear();
        }
        self.inflight = None;
        self.items.iter_mut().filter_map(|s| s.take()).map(|p| p.item).collect()
    }
}

// ---------------------------------------------------------------------------

/// Grader (judges retrieved context) and Critic (judges the answer).
struct VerdictLogic {
    generator: Generator,
    judge_answer: bool,
    /// `SkipHop` (grader: bypass the quality gate) or `CapIterations`
    /// (critic: force-accept so the loop exits) under severe overload.
    knob: DegradeKnob,
    degrade: Arc<OverloadCell>,
    sched_counters: Arc<SchedCounters>,
}

impl StageLogic for VerdictLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        // Severe overload + a degradable verdict stage: pass every
        // request through on the success path without touching the GPU.
        // A skipped grader pretends the context was relevant; a capped
        // critic accepts the current answer, exiting the rewrite loop.
        let skip = matches!(self.knob, DegradeKnob::SkipHop | DegradeKnob::CapIterations)
            && self.degrade.level() == OverloadLevel::Severe;
        if skip {
            for it in items.iter_mut() {
                self.sched_counters.on_degraded();
                it.state.verdict = Some(true);
            }
            return Ok(());
        }
        for it in items.iter_mut() {
            let text = verdict_text(&it.state, self.judge_answer);
            it.state.verdict = Some(self.generator.verdict(&text)?);
        }
        Ok(())
    }
}

/// The judged text, shared by the XLA and echo verdict stages: a fixed
/// prompt prefix, the query, and the answer (critic) or context (grader).
fn verdict_text(state: &crate::exec::messages::RagState, judge_answer: bool) -> Vec<u8> {
    let mut text = Vec::new();
    text.extend_from_slice(if judge_answer {
        b"Is this answer good? ".as_slice()
    } else {
        b"Is this context relevant? ".as_slice()
    });
    text.extend_from_slice(state.query());
    text.push(b' ');
    if judge_answer {
        text.extend_from_slice(state.answer());
    } else {
        state.append_context_to(&mut text);
    }
    text
}

// ---------------------------------------------------------------------------

struct RewriterLogic {
    generator: Generator,
}

impl StageLogic for RewriterLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        // Fallible work first, state mutation after the whole batch
        // succeeded: the worker's error-isolation retry re-runs failed
        // batches item-by-item, and an append-as-you-go loop would
        // double-rewrite the items that had already been processed when
        // a later item errored.
        let mut suffixes = Vec::with_capacity(items.len());
        for it in items.iter() {
            let mut prompt = b"Rewrite: ".to_vec();
            prompt.extend_from_slice(it.state.query());
            let (res, _) = self
                .generator
                .generate_batch(&[GenRequest::greedy(&prompt, 8)], |_, _| {})?;
            suffixes.push(res.into_iter().next().expect("one result").output);
        }
        for (it, suffix) in items.iter_mut().zip(suffixes) {
            // Rewritten query = original + refinement suffix.
            let q = it.state.query_mut();
            q.push(b' ');
            q.extend_from_slice(&suffix);
            it.state.iteration += 1;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------

struct WebSearchLogic {
    shared: Arc<LiveShared>,
}

impl StageLogic for WebSearchLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        // Simulated external latency (the only non-local dependency).
        std::thread::sleep(std::time::Duration::from_millis(15));
        for it in items.iter_mut() {
            // Deterministic "web results": passages keyed by query hash.
            let h: usize = it.state.query().iter().map(|&b| b as usize).sum();
            let n = self.shared.corpus.len();
            let mut ctx = Vec::new();
            for j in 0..self.shared.k_docs {
                let p = &self.shared.corpus.passages[(h + j * 7919) % n];
                let take = p.text.len().min(self.shared.ctx_bytes_per_doc);
                ctx.extend_from_slice(&p.text[..take]);
                ctx.push(b' ');
            }
            // Web results carry no per-doc segmentation: a join merge
            // treats this context as opaque (appended whole).
            it.state.set_unsegmented_context(ctx);
        }
        Ok(())
    }

    fn max_batch(&self) -> usize {
        16
    }
}

// ---------------------------------------------------------------------------

struct ClassifierLogic {
    classifier: Classifier,
}

impl StageLogic for ClassifierLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        for chunk in items.chunks_mut(8) {
            let texts: Vec<&[u8]> = chunk.iter().map(|i| i.state.query()).collect();
            let classes = self.classifier.classify_batch(&texts)?;
            for (it, c) in chunk.iter_mut().zip(classes) {
                it.state.class = Some(c);
            }
        }
        Ok(())
    }

    fn max_batch(&self) -> usize {
        8
    }
}

// ---------------------------------------------------------------------------
// Echo engine: pure-function stages for EngineMode::Echo. The retriever
// and web-search stages above are shared (the retriever via
// AnyEmbedder::Echo); these replace only the XLA-backed stages with
// deterministic digests so the full controller path runs artifact-free.

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// The echo generator's pure answer function: a stable digest of the
/// (context, query) pair over flattened context bytes. Public so tests
/// can compute a request's expected answer independently of the entire
/// serving stack (controller, router, workers, state plumbing).
pub fn echo_answer(context: &[u8], query: &[u8]) -> Vec<u8> {
    let mut h = FNV_OFFSET;
    fnv1a(&mut h, context);
    fnv1a(&mut h, &[0x1f]);
    fnv1a(&mut h, query);
    format!("echo:{h:016x}:{}", context.len()).into_bytes()
}

/// Same digest computed over the state's shared context segments without
/// flattening them (byte-identical to [`echo_answer`] by construction).
fn echo_answer_of(state: &crate::exec::messages::RagState) -> Vec<u8> {
    let mut h = FNV_OFFSET;
    for part in state.context_parts() {
        fnv1a(&mut h, part);
    }
    fnv1a(&mut h, &[0x1f]);
    fnv1a(&mut h, state.query());
    format!("echo:{h:016x}:{}", state.context_len()).into_bytes()
}

/// Echo LLM stage: answers are [`echo_answer`] digests. In continuous
/// mode it runs the same stepped loop as the real generator — one
/// answer byte per decode step into a slotted in-flight batch — so the
/// bench exercises admission/step/retire scheduling, not just batching.
struct EchoGeneratorLogic {
    shared: Arc<LiveShared>,
    slots: Vec<Option<EchoSlot>>,
}

struct EchoSlot {
    item: WorkItem,
    answer: Vec<u8>,
    pos: usize,
    queue_secs: f64,
    admitted: Instant,
}

const ECHO_GEN_SLOTS: usize = 8;

impl EchoGeneratorLogic {
    fn new(shared: Arc<LiveShared>) -> Self {
        EchoGeneratorLogic { shared, slots: (0..ECHO_GEN_SLOTS).map(|_| None).collect() }
    }
}

impl StageLogic for EchoGeneratorLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        for it in items.iter_mut() {
            // KV prefix probe keeps the reuse counters and attribution
            // discount live in echo mode too.
            let kv = kv_probe(&self.shared, &it.state);
            it.service_weight = kv * (1.0 + it.state.context_len() as f64 / 64.0);
            it.state.set_answer(echo_answer_of(&it.state));
        }
        Ok(())
    }

    fn max_batch(&self) -> usize {
        ECHO_GEN_SLOTS
    }

    fn stepped(&mut self) -> Option<&mut dyn SteppedStage> {
        if self.shared.continuous_batching {
            Some(self)
        } else {
            None
        }
    }
}

impl SteppedStage for EchoGeneratorLogic {
    fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn free_slots(&self) -> usize {
        ECHO_GEN_SLOTS - self.occupancy()
    }

    fn admit(&mut self, mut item: WorkItem) -> Vec<StepDone> {
        let queue_secs = item.enqueued_at.elapsed().as_secs_f64();
        kv_probe(&self.shared, &item.state);
        let answer = echo_answer_of(&item.state);
        item.state.clear_answer();
        match self.slots.iter_mut().find(|s| s.is_none()) {
            Some(slot) => {
                *slot =
                    Some(EchoSlot { item, answer, pos: 0, queue_secs, admitted: Instant::now() });
                Vec::new()
            }
            None => vec![StepDone {
                item,
                service_secs: 0.0,
                queue_secs,
                error: Some("echo generator admitted past capacity".into()),
            }],
        }
    }

    fn step(&mut self) -> Result<Vec<StepDone>> {
        let mut retired = Vec::new();
        for slot in self.slots.iter_mut() {
            let Some(s) = slot.as_mut() else { continue };
            // Stream one answer byte per decode step.
            s.item.state.answer_mut().push(s.answer[s.pos]);
            s.pos += 1;
            if s.pos == s.answer.len() {
                let EchoSlot { mut item, answer, queue_secs, admitted, .. } =
                    slot.take().expect("slot occupied");
                item.state.set_answer(answer);
                retired.push(StepDone {
                    item,
                    service_secs: admitted.elapsed().as_secs_f64(),
                    queue_secs,
                    error: None,
                });
            }
        }
        Ok(retired)
    }

    fn drain(&mut self) -> Vec<WorkItem> {
        self.slots.iter_mut().filter_map(|s| s.take()).map(|s| s.item).collect()
    }
}

/// Echo grader/critic: verdict = byte-sum parity of the same judged
/// text the XLA stage builds; honors the severe-overload skip knobs.
struct EchoVerdictLogic {
    judge_answer: bool,
    knob: DegradeKnob,
    degrade: Arc<OverloadCell>,
    sched_counters: Arc<SchedCounters>,
}

impl StageLogic for EchoVerdictLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        let skip = matches!(self.knob, DegradeKnob::SkipHop | DegradeKnob::CapIterations)
            && self.degrade.level() == OverloadLevel::Severe;
        if skip {
            for it in items.iter_mut() {
                self.sched_counters.on_degraded();
                it.state.verdict = Some(true);
            }
            return Ok(());
        }
        for it in items.iter_mut() {
            let text = verdict_text(&it.state, self.judge_answer);
            let sum: u64 = text.iter().map(|&b| b as u64).sum();
            it.state.verdict = Some(sum % 2 == 0);
        }
        Ok(())
    }
}

/// Echo rewriter: appends a deterministic query-hash suffix and bumps
/// the iteration counter, same shape as the XLA rewrite.
struct EchoRewriterLogic;

impl StageLogic for EchoRewriterLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        for it in items.iter_mut() {
            let mut h = FNV_OFFSET;
            fnv1a(&mut h, it.state.query());
            let suffix = format!("r{:04x}", h & 0xffff);
            let q = it.state.query_mut();
            q.push(b' ');
            q.extend_from_slice(suffix.as_bytes());
            it.state.iteration += 1;
        }
        Ok(())
    }
}

/// Echo classifier: query-hash modulo the A-RAG class count.
struct EchoClassifierLogic;

impl StageLogic for EchoClassifierLogic {
    fn process_batch(&mut self, items: &mut [WorkItem]) -> Result<()> {
        for it in items.iter_mut() {
            let mut h = FNV_OFFSET;
            fnv1a(&mut h, it.state.query());
            it.state.class = Some((h % 3) as u8);
        }
        Ok(())
    }

    fn max_batch(&self) -> usize {
        8
    }
}

fn spawn_echo_for_kind(
    name: String,
    kind: &ComponentKind,
    knob: DegradeKnob,
    shared: Arc<LiveShared>,
) -> WorkerHandle {
    match kind {
        ComponentKind::Retriever => spawn_worker(name, move || {
            Ok(Box::new(RetrieverLogic { embedder: AnyEmbedder::Echo, shared, knob })
                as Box<dyn StageLogic>)
        }),
        ComponentKind::Generator => spawn_worker(name, move || {
            Ok(Box::new(EchoGeneratorLogic::new(shared)) as Box<dyn StageLogic>)
        }),
        ComponentKind::Grader => spawn_worker(name, move || {
            Ok(Box::new(EchoVerdictLogic {
                judge_answer: false,
                knob,
                degrade: shared.degrade.clone(),
                sched_counters: shared.sched_counters.clone(),
            }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Critic => spawn_worker(name, move || {
            Ok(Box::new(EchoVerdictLogic {
                judge_answer: true,
                knob,
                degrade: shared.degrade.clone(),
                sched_counters: shared.sched_counters.clone(),
            }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Rewriter => spawn_worker(name, move || {
            let _keep = shared;
            Ok(Box::new(EchoRewriterLogic) as Box<dyn StageLogic>)
        }),
        ComponentKind::WebSearch => spawn_worker(name, move || {
            Ok(Box::new(WebSearchLogic { shared }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Classifier => spawn_worker(name, move || {
            let _keep = shared;
            Ok(Box::new(EchoClassifierLogic) as Box<dyn StageLogic>)
        }),
        other => {
            let kind_name = other.name().to_string();
            spawn_worker(name, move || -> Result<Box<dyn StageLogic>> {
                let _keep = shared;
                anyhow::bail!("no live executor for component kind '{kind_name}'")
            })
        }
    }
}

// ---------------------------------------------------------------------------

/// Spawn a worker instance for a component kind. Engines are constructed
/// inside the worker thread (cold start), mirroring §3.1's stateful
/// actors. `knob` is the node's degrade annotation; workers honor it
/// against the shared overload cell.
pub fn spawn_for_kind(
    name: String,
    kind: &ComponentKind,
    knob: DegradeKnob,
    shared: Arc<LiveShared>,
) -> WorkerHandle {
    if shared.engine == EngineMode::Echo {
        return spawn_echo_for_kind(name, kind, knob, shared);
    }
    let dir = shared.artifacts.clone();
    match kind {
        ComponentKind::Retriever => spawn_worker(name, move || {
            Ok(Box::new(RetrieverLogic {
                embedder: AnyEmbedder::Xla(Embedder::new(&dir)?),
                shared,
                knob,
            }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Generator => spawn_worker(name, move || {
            Ok(Box::new(GeneratorLogic {
                generator: Generator::new(&dir)?,
                shared,
                inflight: None,
                items: Vec::new(),
            }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Grader => spawn_worker(name, move || {
            Ok(Box::new(VerdictLogic {
                generator: Generator::new(&dir)?,
                judge_answer: false,
                knob,
                degrade: shared.degrade.clone(),
                sched_counters: shared.sched_counters.clone(),
            }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Critic => spawn_worker(name, move || {
            Ok(Box::new(VerdictLogic {
                generator: Generator::new(&dir)?,
                judge_answer: true,
                knob,
                degrade: shared.degrade.clone(),
                sched_counters: shared.sched_counters.clone(),
            }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Rewriter => spawn_worker(name, move || {
            Ok(Box::new(RewriterLogic { generator: Generator::new(&dir)? }) as Box<dyn StageLogic>)
        }),
        ComponentKind::WebSearch => spawn_worker(name, move || {
            Ok(Box::new(WebSearchLogic { shared }) as Box<dyn StageLogic>)
        }),
        ComponentKind::Classifier => spawn_worker(name, move || {
            Ok(Box::new(ClassifierLogic { classifier: Classifier::new(&dir)? })
                as Box<dyn StageLogic>)
        }),
        other => {
            let kind_name = other.name().to_string();
            spawn_worker(name, move || -> Result<Box<dyn StageLogic>> {
                let _keep = shared; // kinds without executors fail at init
                anyhow::bail!("no live executor for component kind '{kind_name}'")
            })
        }
    }
}

/// Build the shared deployment state: generate the corpus, embed it with
/// the real embedder, build the sharded IVF index (`n_shards` corpus
/// partitions searched scatter-gather style, stored f32 or SQ8 per
/// `quantization`), and stand up the request cache (`cache`: None
/// disables memoization) plus the generator-side KV prefix cache
/// (`kv_cache`: None disables prefix tracking). With
/// [`EngineMode::Echo`] the corpus is embedded with the deterministic
/// hash embedding instead of the XLA artifact — no artifacts touched.
#[allow(clippy::too_many_arguments)]
pub fn build_live_shared(
    artifacts: PathBuf,
    corpus_size: usize,
    n_topics: usize,
    n_shards: usize,
    cache: Option<CacheConfig>,
    kv_cache: Option<KvCacheConfig>,
    quantization: crate::retrieval::Quantization,
    seed: u64,
    engine: EngineMode,
) -> Result<LiveShared> {
    let corpus = Arc::new(Corpus::generate(corpus_size, n_topics, 64, seed));
    let texts: Vec<Vec<u8>> = corpus.passages.iter().map(|p| p.text.clone()).collect();
    let (embs, dim) = match engine {
        EngineMode::Artifacts => {
            let embedder = Embedder::new(&artifacts)?;
            let dim = embedder.dim();
            (embedder.embed_all(&texts)?, dim)
        }
        EngineMode::Echo => {
            let embs: Vec<Vec<f32>> =
                texts.iter().map(|t| Corpus::hash_embed(t, ECHO_EMBED_DIM)).collect();
            (embs, ECHO_EMBED_DIM)
        }
    };
    let mut flat = Vec::with_capacity(embs.len() * dim);
    for e in &embs {
        flat.extend_from_slice(e);
    }
    let index = Arc::new(ShardedIndex::build(
        flat,
        dim,
        ShardParams {
            n_shards: n_shards.max(1),
            ivf: IvfParams {
                n_lists: (corpus_size / 64).max(4),
                kmeans_iters: 6,
                seed,
                quantization,
                ..IvfParams::default()
            },
        },
    ));
    Ok(LiveShared {
        engine,
        corpus,
        index,
        cache: cache.map(|cfg| Arc::new(QueryCache::new(cfg))),
        kv_cache: kv_cache.map(|cfg| Arc::new(KvPrefixCache::new(cfg))),
        degrade: Arc::new(OverloadCell::new()),
        sched_counters: Arc::new(SchedCounters::new()),
        epoch: Instant::now(),
        artifacts,
        k_docs: 4,
        search_ef: 256,
        max_new_tokens: 24,
        ctx_bytes_per_doc: 48,
        max_iterations: 2,
        continuous_batching: true,
    })
}
