//! Live execution layer: component instances as worker threads running
//! real XLA artifacts, coordinated by `coordinator::controller`.
//!
//! PJRT handles are not `Send`, so each worker thread *builds its own*
//! engine (generator / embedder / classifier) at startup — matching the
//! paper's long-running stateful actors with significant cold-start cost
//! (§3.1), which is exactly why `base_instances` exists.

pub mod components;
pub mod messages;
pub mod worker;

pub use components::EngineMode;
pub use messages::{Done, RagState, WorkItem};
pub use worker::{spawn_worker, StageLogic, StepDone, SteppedStage, WorkerHandle};
