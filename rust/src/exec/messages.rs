//! Messages between the controller and live workers.

use std::sync::mpsc::Sender;

use crate::spec::graph::{MergePolicy, NodeId};

/// Request-scoped pipeline state threaded through the stages — the live
/// equivalent of the intermediate values that flow producer→consumer in
/// the paper's data plane (the controller re-ingests it only to make
/// control-flow decisions, mirroring §3.3's control/data separation).
#[derive(Clone, Debug, Default)]
pub struct RagState {
    pub query: Vec<u8>,
    /// Retrieved context (concatenated passages).
    pub context: Vec<u8>,
    /// Byte length of each retrieved passage's chunk inside `context`,
    /// parallel to `doc_ids` when populated by retrieval (other
    /// producers, e.g. web search, leave it empty). Lets a fork/join
    /// barrier union branch contexts with per-document dedup.
    pub ctx_segments: Vec<usize>,
    /// Generated answer so far.
    pub answer: Vec<u8>,
    /// Last grader/critic verdict.
    pub verdict: Option<bool>,
    /// Query-complexity class (A-RAG).
    pub class: Option<u8>,
    /// Recursion depth (rewrite loops).
    pub iteration: u32,
    /// Retrieved passage ids (diagnostics).
    pub doc_ids: Vec<usize>,
}

impl RagState {
    pub fn new(query: &[u8]) -> Self {
        RagState { query: query.to_vec(), ..Default::default() }
    }

    /// Merge the states of completed fork branches at a join barrier
    /// (`states` in branch arrival order; must be non-empty).
    ///
    /// * [`MergePolicy::First`] — the first state wins verbatim (the
    ///   natural pairing for `FirstK(1)` races).
    /// * [`MergePolicy::Union`] — retrieval results are unioned:
    ///   `doc_ids` deduplicate across branches (first occurrence wins)
    ///   and each branch's context contributes only its unseen documents'
    ///   chunks, preserving per-branch score order (branch-major concat).
    ///   Branches without per-document segmentation (web search) append
    ///   their whole context. Scalars take the first populated value;
    ///   `iteration` takes the max (a rewrite in ANY branch counts
    ///   toward the loop budget).
    pub fn merge(policy: MergePolicy, mut states: Vec<RagState>) -> RagState {
        debug_assert!(!states.is_empty(), "a join merges at least one branch");
        if states.len() == 1 || policy == MergePolicy::First {
            return states.swap_remove(0);
        }
        let mut out = RagState::new(&states[0].query);
        let mut seen = std::collections::HashSet::new();
        for s in &states {
            if s.ctx_segments.len() == s.doc_ids.len() && !s.doc_ids.is_empty() {
                let mut off = 0usize;
                for (&id, &len) in s.doc_ids.iter().zip(&s.ctx_segments) {
                    let end = (off + len).min(s.context.len());
                    if seen.insert(id) {
                        out.doc_ids.push(id);
                        out.ctx_segments.push(end - off);
                        out.context.extend_from_slice(&s.context[off..end]);
                    }
                    off = end;
                }
            } else if !s.context.is_empty() {
                // Unsegmented producer: no per-doc dedup possible.
                out.context.extend_from_slice(&s.context);
                out.ctx_segments.clear(); // segmentation no longer covers doc_ids
                for &id in &s.doc_ids {
                    if seen.insert(id) {
                        out.doc_ids.push(id);
                    }
                }
            }
            if out.answer.is_empty() && !s.answer.is_empty() {
                out.answer = s.answer.clone();
            }
            if out.verdict.is_none() {
                out.verdict = s.verdict;
            }
            if out.class.is_none() {
                out.class = s.class;
            }
            out.iteration = out.iteration.max(s.iteration);
        }
        // An unsegmented contributor invalidated the segment map above;
        // make that explicit so a later join treats the merged context
        // as opaque instead of mis-slicing it.
        if out.ctx_segments.len() != out.doc_ids.len() {
            out.ctx_segments.clear();
        }
        out
    }
}

/// A unit of work dispatched to a worker instance.
pub struct WorkItem {
    pub req: u64,
    pub node: NodeId,
    /// Fork-branch id (0 = the request's trunk): tags which sibling
    /// subtask this item belongs to, so the controller's join cells can
    /// tell branch completions apart.
    pub branch: u32,
    pub state: RagState,
    /// Controller timestamp at enqueue (for queue-wait accounting).
    pub enqueued_at: std::time::Instant,
    /// Per-item service-attribution weight, written by the stage during
    /// `process_batch` (e.g. the generator's per-slot prefill + decode
    /// cost). The worker splits the batch's wall time proportionally;
    /// stages that leave it at the default 1.0 keep the uniform split.
    pub service_weight: f64,
    /// Reply channel.
    pub done: Sender<Done>,
}

impl WorkItem {
    /// Build an item with the default (uniform) service weight on the
    /// request trunk.
    pub fn new(req: u64, node: NodeId, state: RagState, done: Sender<Done>) -> WorkItem {
        WorkItem {
            req,
            node,
            branch: 0,
            state,
            enqueued_at: std::time::Instant::now(),
            service_weight: 1.0,
            done,
        }
    }

    /// Build an item for a fork-branch subtask.
    pub fn for_branch(
        req: u64,
        node: NodeId,
        branch: u32,
        state: RagState,
        done: Sender<Done>,
    ) -> WorkItem {
        WorkItem { branch, ..WorkItem::new(req, node, state, done) }
    }
}

/// Completion notification back to the controller.
pub struct Done {
    pub req: u64,
    pub node: NodeId,
    pub instance: usize,
    /// Fork-branch id the completed item carried (0 = trunk).
    pub branch: u32,
    pub state: RagState,
    /// Seconds of actual stage execution.
    pub service_secs: f64,
    /// Seconds spent queued at the worker.
    pub queue_secs: f64,
    /// Worker-reported error, if any (the controller fails the request).
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn retrieved(query: &[u8], ids: &[usize]) -> RagState {
        let mut s = RagState::new(query);
        for &id in ids {
            let chunk = format!("doc{id} ");
            s.context.extend_from_slice(chunk.as_bytes());
            s.ctx_segments.push(chunk.len());
            s.doc_ids.push(id);
        }
        s
    }

    #[test]
    fn union_merge_dedups_doc_ids_and_context() {
        let a = retrieved(b"q", &[3, 1, 2]);
        let b = retrieved(b"q", &[1, 4]);
        let m = RagState::merge(MergePolicy::Union, vec![a, b]);
        // First occurrence wins; per-branch score order preserved.
        assert_eq!(m.doc_ids, vec![3, 1, 2, 4]);
        assert_eq!(m.context, b"doc3 doc1 doc2 doc4 ".to_vec());
        assert_eq!(m.ctx_segments.len(), 4);
        assert_eq!(m.query, b"q".to_vec());
    }

    #[test]
    fn union_merge_appends_unsegmented_context_whole() {
        let a = retrieved(b"q", &[7]);
        let mut web = RagState::new(b"q");
        web.context = b"web results ".to_vec(); // no doc ids / segments
        let m = RagState::merge(MergePolicy::Union, vec![a, web]);
        assert_eq!(m.doc_ids, vec![7]);
        assert!(m.context.ends_with(b"web results "));
        // Segment map no longer covers the context → cleared.
        assert!(m.ctx_segments.is_empty());
    }

    #[test]
    fn first_merge_is_winner_takes_all() {
        let a = retrieved(b"q", &[1]);
        let b = retrieved(b"q", &[2]);
        let m = RagState::merge(MergePolicy::First, vec![a, b]);
        assert_eq!(m.doc_ids, vec![1]);
    }

    #[test]
    fn scalar_fields_take_first_populated_and_max_iteration() {
        let mut a = retrieved(b"q", &[1]);
        a.iteration = 1;
        let mut b = retrieved(b"q", &[2]);
        b.verdict = Some(true);
        b.class = Some(2);
        b.iteration = 3;
        let m = RagState::merge(MergePolicy::Union, vec![a, b]);
        assert_eq!(m.verdict, Some(true));
        assert_eq!(m.class, Some(2));
        assert_eq!(m.iteration, 3);
    }
}
