//! Messages between the controller and live workers.
//!
//! `RagState` is the per-request payload the controller re-ingests after
//! every hop. The live hot loop clones it once per dispatch and once per
//! fork branch, so its representation decides whether fan-out is a
//! memcpy storm or a pointer bump: every buffer here is an `Arc`'d
//! immutable segment (`Bytes`), contexts are *lists* of such segments
//! (`ContextBuf`), and mutation goes through copy-on-write accessors
//! (`Arc::make_mut`) so only the stages that actually rewrite a field
//! pay for a copy. Cloning a state is eight pointer/word copies; merging
//! branch contexts at a join unions segment lists instead of copying
//! bytes whenever the branches are disjoint.

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::spec::graph::{MergePolicy, NodeId};

/// A cheaply-cloneable immutable byte buffer.
type Bytes = Arc<Vec<u8>>;

/// Retrieved-context bytes as a list of shared immutable parts.
///
/// Logically this is one contiguous `Vec<u8>` (`len` is the total byte
/// length; readers iterate [`ContextBuf::parts`] or flatten with
/// [`ContextBuf::append_to`]); physically each part is an `Arc` that a
/// join can adopt from a branch without touching the bytes. Invariant:
/// no stored part is empty, and `len` equals the sum of part lengths.
#[derive(Clone, Debug, Default)]
struct ContextBuf {
    parts: Arc<Vec<Bytes>>,
    len: usize,
}

impl ContextBuf {
    fn from_vec(v: Vec<u8>) -> ContextBuf {
        let len = v.len();
        if len == 0 {
            return ContextBuf::default();
        }
        ContextBuf { parts: Arc::new(vec![Arc::new(v)]), len }
    }

    fn from_parts(parts: Vec<Bytes>) -> ContextBuf {
        debug_assert!(parts.iter().all(|p| !p.is_empty()), "no empty parts stored");
        let len = parts.iter().map(|p| p.len()).sum();
        ContextBuf { parts: Arc::new(parts), len }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn parts(&self) -> impl Iterator<Item = &[u8]> {
        self.parts.iter().map(|p| p.as_slice())
    }

    fn append_to(&self, out: &mut Vec<u8>) {
        for p in self.parts.iter() {
            out.extend_from_slice(p);
        }
    }

    /// Append the logical byte range `start..end` to `out`, walking the
    /// part list (ranges may straddle part boundaries after a merge).
    fn slice_append(&self, out: &mut Vec<u8>, start: usize, end: usize) {
        let mut off = 0usize;
        for p in self.parts.iter() {
            let plen = p.len();
            let lo = start.max(off);
            let hi = end.min(off + plen);
            if lo < hi {
                out.extend_from_slice(&p[lo - off..hi - off]);
            }
            off += plen;
            if off >= end {
                break;
            }
        }
    }

    fn to_vec(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len);
        self.append_to(&mut out);
        out
    }
}

/// Request-scoped pipeline state threaded through the stages — the live
/// equivalent of the intermediate values that flow producer→consumer in
/// the paper's data plane (the controller re-ingests it only to make
/// control-flow decisions, mirroring §3.3's control/data separation).
///
/// Buffers are private behind copy-on-write accessors so clones share
/// storage; the control-flow scalars (`verdict`, `class`, `iteration`)
/// stay public — they are `Copy` and the routing logic reads them on
/// every hop.
#[derive(Clone, Debug, Default)]
pub struct RagState {
    query: Bytes,
    /// Retrieved context (concatenated passages) as shared segments.
    context: ContextBuf,
    /// Byte length of each retrieved passage's chunk inside the context,
    /// parallel to `doc_ids` when populated by retrieval (other
    /// producers, e.g. web search, leave it empty). Lets a fork/join
    /// barrier union branch contexts with per-document dedup.
    ctx_segments: Arc<Vec<usize>>,
    /// Generated answer so far.
    answer: Bytes,
    /// Last grader/critic verdict.
    pub verdict: Option<bool>,
    /// Query-complexity class (A-RAG).
    pub class: Option<u8>,
    /// Recursion depth (rewrite loops).
    pub iteration: u32,
    /// Retrieved passage ids (diagnostics).
    doc_ids: Arc<Vec<usize>>,
}

impl RagState {
    pub fn new(query: &[u8]) -> Self {
        RagState { query: Arc::new(query.to_vec()), ..Default::default() }
    }

    pub fn query(&self) -> &[u8] {
        &self.query
    }

    /// Copy-on-write access to the query (rewriter stages).
    pub fn query_mut(&mut self) -> &mut Vec<u8> {
        Arc::make_mut(&mut self.query)
    }

    pub fn answer(&self) -> &[u8] {
        &self.answer
    }

    /// Copy-on-write access to the answer (the generator streams decoded
    /// bytes here).
    pub fn answer_mut(&mut self) -> &mut Vec<u8> {
        Arc::make_mut(&mut self.answer)
    }

    pub fn set_answer(&mut self, answer: Vec<u8>) {
        self.answer = Arc::new(answer);
    }

    /// Clear the answer in place when the buffer is unshared (the common
    /// case on the generator's admit path), else drop to a fresh one.
    pub fn clear_answer(&mut self) {
        match Arc::get_mut(&mut self.answer) {
            Some(a) => a.clear(),
            None => self.answer = Bytes::default(),
        }
    }

    /// Consume the state, yielding the answer without a copy when this
    /// is the last reference (the controller's response path).
    pub fn into_answer(self) -> Vec<u8> {
        Arc::try_unwrap(self.answer).unwrap_or_else(|a| (*a).clone())
    }

    pub fn doc_ids(&self) -> &[usize] {
        &self.doc_ids
    }

    pub fn ctx_segments(&self) -> &[usize] {
        &self.ctx_segments
    }

    /// Replace the retrieval triple wholesale (retriever stages).
    pub fn set_context(&mut self, context: Vec<u8>, doc_ids: Vec<usize>, segments: Vec<usize>) {
        self.context = ContextBuf::from_vec(context);
        self.doc_ids = Arc::new(doc_ids);
        self.ctx_segments = Arc::new(segments);
    }

    /// Replace the context with an unsegmented blob (web search): the
    /// segment map is cleared but `doc_ids` are retained as diagnostics
    /// of the earlier retrieval.
    pub fn set_unsegmented_context(&mut self, context: Vec<u8>) {
        self.context = ContextBuf::from_vec(context);
        self.ctx_segments = Arc::default();
    }

    pub fn context_len(&self) -> usize {
        self.context.len()
    }

    pub fn context_is_empty(&self) -> bool {
        self.context.is_empty()
    }

    /// The context's shared segments, in logical order (prompt builders
    /// and hashers walk these instead of flattening).
    pub fn context_parts(&self) -> impl Iterator<Item = &[u8]> {
        self.context.parts()
    }

    /// Append the flattened context bytes to `out`.
    pub fn append_context_to(&self, out: &mut Vec<u8>) {
        self.context.append_to(out);
    }

    /// Flatten the context into a fresh `Vec` (tests / diagnostics; the
    /// hot path iterates `context_parts` instead).
    pub fn context_to_vec(&self) -> Vec<u8> {
        self.context.to_vec()
    }

    /// Merge the states of completed fork branches at a join barrier
    /// (`states` in branch arrival order; must be non-empty).
    ///
    /// * [`MergePolicy::First`] — the first state wins verbatim (the
    ///   natural pairing for `FirstK(1)` races); the winner's buffers
    ///   move out without a copy.
    /// * [`MergePolicy::Union`] — retrieval results are unioned:
    ///   `doc_ids` deduplicate across branches (first occurrence wins)
    ///   and each branch's context contributes only its unseen documents'
    ///   chunks, preserving per-branch score order (branch-major concat).
    ///   Branches without per-document segmentation (web search) append
    ///   their whole context. Scalars take the first populated value;
    ///   `iteration` takes the max (a rewrite in ANY branch counts
    ///   toward the loop budget). A branch whose documents are all
    ///   unseen contributes its context *segments by pointer* — bytes
    ///   are copied only for branches that overlap an earlier one.
    pub fn merge(policy: MergePolicy, mut states: Vec<RagState>) -> RagState {
        debug_assert!(!states.is_empty(), "a join merges at least one branch");
        if states.len() == 1 || policy == MergePolicy::First {
            return states.swap_remove(0);
        }
        let mut parts: Vec<Bytes> = Vec::new();
        // Owned accumulator for partially-copied chunks; flushed into
        // `parts` before any pointer-shared segment to preserve order.
        let mut pending: Vec<u8> = Vec::new();
        let mut doc_ids: Vec<usize> = Vec::new();
        let mut ctx_segments: Vec<usize> = Vec::new();
        let mut answer: Option<Bytes> = None;
        let mut verdict = None;
        let mut class = None;
        let mut iteration = 0u32;
        let mut seen = std::collections::HashSet::new();
        for s in &states {
            if s.ctx_segments.len() == s.doc_ids.len() && !s.doc_ids.is_empty() {
                let clen = s.context.len();
                // Fast path precheck: every document unseen (including
                // in-branch duplicates) and the clamped segment walk
                // covers the whole context — then the branch's segments
                // can be adopted by pointer, byte-for-byte identical to
                // the copying walk below.
                let mut walk_end = 0usize;
                for &len in s.ctx_segments.iter() {
                    walk_end = (walk_end + len).min(clen);
                }
                let all_unseen = s
                    .doc_ids
                    .iter()
                    .enumerate()
                    .all(|(i, id)| !seen.contains(id) && !s.doc_ids[..i].contains(id));
                if all_unseen && walk_end == clen {
                    if !pending.is_empty() {
                        parts.push(Arc::new(std::mem::take(&mut pending)));
                    }
                    for p in s.context.parts.iter() {
                        parts.push(p.clone());
                    }
                    let mut off = 0usize;
                    for (&id, &len) in s.doc_ids.iter().zip(s.ctx_segments.iter()) {
                        let end = (off + len).min(clen);
                        seen.insert(id);
                        doc_ids.push(id);
                        ctx_segments.push(end - off);
                        off = end;
                    }
                } else {
                    let mut off = 0usize;
                    for (&id, &len) in s.doc_ids.iter().zip(s.ctx_segments.iter()) {
                        let end = (off + len).min(clen);
                        if seen.insert(id) {
                            doc_ids.push(id);
                            ctx_segments.push(end - off);
                            s.context.slice_append(&mut pending, off, end);
                        }
                        off = end;
                    }
                }
            } else if !s.context.is_empty() {
                // Unsegmented producer: no per-doc dedup possible.
                if !pending.is_empty() {
                    parts.push(Arc::new(std::mem::take(&mut pending)));
                }
                for p in s.context.parts.iter() {
                    parts.push(p.clone());
                }
                ctx_segments.clear(); // segmentation no longer covers doc_ids
                for &id in s.doc_ids.iter() {
                    if seen.insert(id) {
                        doc_ids.push(id);
                    }
                }
            }
            if answer.is_none() && !s.answer.is_empty() {
                answer = Some(s.answer.clone());
            }
            if verdict.is_none() {
                verdict = s.verdict;
            }
            if class.is_none() {
                class = s.class;
            }
            iteration = iteration.max(s.iteration);
        }
        if !pending.is_empty() {
            parts.push(Arc::new(pending));
        }
        // An unsegmented contributor invalidated the segment map above;
        // make that explicit so a later join treats the merged context
        // as opaque instead of mis-slicing it.
        if ctx_segments.len() != doc_ids.len() {
            ctx_segments.clear();
        }
        RagState {
            query: states[0].query.clone(),
            context: ContextBuf::from_parts(parts),
            ctx_segments: Arc::new(ctx_segments),
            answer: answer.unwrap_or_default(),
            verdict,
            class,
            iteration,
            doc_ids: Arc::new(doc_ids),
        }
    }
}

/// A unit of work dispatched to a worker instance.
pub struct WorkItem {
    pub req: u64,
    pub node: NodeId,
    /// Fork-branch id (0 = the request's trunk): tags which sibling
    /// subtask this item belongs to, so the controller's join cells can
    /// tell branch completions apart.
    pub branch: u32,
    pub state: RagState,
    /// Controller timestamp at enqueue (for queue-wait accounting).
    pub enqueued_at: std::time::Instant,
    /// Per-item service-attribution weight, written by the stage during
    /// `process_batch` (e.g. the generator's per-slot prefill + decode
    /// cost). The worker splits the batch's wall time proportionally;
    /// stages that leave it at the default 1.0 keep the uniform split.
    pub service_weight: f64,
    /// Reply channel, shared by every in-flight item (an `Arc` bump per
    /// dispatch instead of a channel-handle clone).
    pub done: Arc<Sender<Done>>,
}

impl WorkItem {
    /// Build an item with the default (uniform) service weight on the
    /// request trunk.
    pub fn new(req: u64, node: NodeId, state: RagState, done: Arc<Sender<Done>>) -> WorkItem {
        WorkItem {
            req,
            node,
            branch: 0,
            state,
            enqueued_at: std::time::Instant::now(),
            service_weight: 1.0,
            done,
        }
    }

    /// Build an item for a fork-branch subtask.
    pub fn for_branch(
        req: u64,
        node: NodeId,
        branch: u32,
        state: RagState,
        done: Arc<Sender<Done>>,
    ) -> WorkItem {
        WorkItem { branch, ..WorkItem::new(req, node, state, done) }
    }
}

/// Completion notification back to the controller.
pub struct Done {
    pub req: u64,
    pub node: NodeId,
    pub instance: usize,
    /// Fork-branch id the completed item carried (0 = trunk).
    pub branch: u32,
    pub state: RagState,
    /// Seconds of actual stage execution.
    pub service_secs: f64,
    /// Seconds spent queued at the worker.
    pub queue_secs: f64,
    /// Worker-reported error, if any (the controller fails the request).
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    fn retrieved(query: &[u8], ids: &[usize]) -> RagState {
        let mut s = RagState::new(query);
        let mut ctx = Vec::new();
        let mut segs = Vec::new();
        for &id in ids {
            let chunk = format!("doc{id} ");
            ctx.extend_from_slice(chunk.as_bytes());
            segs.push(chunk.len());
        }
        s.set_context(ctx, ids.to_vec(), segs);
        s
    }

    #[test]
    fn union_merge_dedups_doc_ids_and_context() {
        let a = retrieved(b"q", &[3, 1, 2]);
        let b = retrieved(b"q", &[1, 4]);
        let m = RagState::merge(MergePolicy::Union, vec![a, b]);
        // First occurrence wins; per-branch score order preserved.
        assert_eq!(m.doc_ids(), &[3, 1, 2, 4][..]);
        assert_eq!(m.context_to_vec(), b"doc3 doc1 doc2 doc4 ".to_vec());
        assert_eq!(m.ctx_segments().len(), 4);
        assert_eq!(m.query(), b"q".as_slice());
    }

    #[test]
    fn union_merge_appends_unsegmented_context_whole() {
        let a = retrieved(b"q", &[7]);
        let mut web = RagState::new(b"q");
        web.set_unsegmented_context(b"web results ".to_vec()); // no doc ids / segments
        let m = RagState::merge(MergePolicy::Union, vec![a, web]);
        assert_eq!(m.doc_ids(), &[7][..]);
        assert!(m.context_to_vec().ends_with(b"web results "));
        // Segment map no longer covers the context → cleared.
        assert!(m.ctx_segments().is_empty());
    }

    #[test]
    fn first_merge_is_winner_takes_all() {
        let a = retrieved(b"q", &[1]);
        let b = retrieved(b"q", &[2]);
        let m = RagState::merge(MergePolicy::First, vec![a, b]);
        assert_eq!(m.doc_ids(), &[1][..]);
    }

    #[test]
    fn scalar_fields_take_first_populated_and_max_iteration() {
        let mut a = retrieved(b"q", &[1]);
        a.iteration = 1;
        let mut b = retrieved(b"q", &[2]);
        b.verdict = Some(true);
        b.class = Some(2);
        b.iteration = 3;
        let m = RagState::merge(MergePolicy::Union, vec![a, b]);
        assert_eq!(m.verdict, Some(true));
        assert_eq!(m.class, Some(2));
        assert_eq!(m.iteration, 3);
    }

    #[test]
    fn union_merge_overlap_copies_only_unseen_chunks() {
        let a = retrieved(b"q", &[1, 2]);
        let b = retrieved(b"q", &[2, 3]);
        let m = RagState::merge(MergePolicy::Union, vec![a, b]);
        assert_eq!(m.context_to_vec(), b"doc1 doc2 doc3 ".to_vec());
        assert_eq!(m.doc_ids(), &[1, 2, 3][..]);
        assert_eq!(m.ctx_segments(), &[5, 5, 5][..]);
    }

    #[test]
    fn segmented_after_unsegmented_keeps_parity_check_semantics() {
        // An unsegmented contributor clears the segment map mid-merge; a
        // later segmented branch re-populates it, and the final parity
        // check against doc_ids decides whether it survives.
        let a = retrieved(b"q", &[1]);
        let mut web = RagState::new(b"q");
        web.set_unsegmented_context(b"web ".to_vec());
        let b = retrieved(b"q", &[2]);
        let m = RagState::merge(MergePolicy::Union, vec![a, web, b]);
        assert!(m.ctx_segments().is_empty());
        assert_eq!(m.doc_ids(), &[1, 2][..]);
        assert_eq!(m.context_to_vec(), b"doc1 web doc2 ".to_vec());
    }

    // -- zero-copy representation ------------------------------------------

    #[test]
    fn clone_shares_buffers_by_pointer() {
        let mut s = RagState::new(b"query");
        s.set_context(b"doc1 doc2 ".to_vec(), vec![1, 2], vec![5, 5]);
        s.set_answer(b"ans".to_vec());
        let c = s.clone();
        assert!(Arc::ptr_eq(&s.query, &c.query));
        assert!(Arc::ptr_eq(&s.answer, &c.answer));
        assert!(Arc::ptr_eq(&s.context.parts, &c.context.parts));
        assert!(Arc::ptr_eq(&s.doc_ids, &c.doc_ids));
        assert!(Arc::ptr_eq(&s.ctx_segments, &c.ctx_segments));
    }

    #[test]
    fn first_merge_moves_winner_buffers() {
        let a = retrieved(b"q", &[1]);
        let winner_parts = a.context.parts.clone();
        let m = RagState::merge(MergePolicy::First, vec![a, retrieved(b"q", &[2])]);
        assert!(Arc::ptr_eq(&m.context.parts, &winner_parts));
    }

    #[test]
    fn union_merge_of_disjoint_branches_shares_context_segments() {
        let a = retrieved(b"q", &[1, 2]);
        let b = retrieved(b"q", &[3]);
        let ap = a.context.parts[0].clone();
        let bp = b.context.parts[0].clone();
        let m = RagState::merge(MergePolicy::Union, vec![a, b]);
        // Disjoint branches contribute their segment Arcs, not copies.
        assert!(m.context.parts.iter().any(|p| Arc::ptr_eq(p, &ap)));
        assert!(m.context.parts.iter().any(|p| Arc::ptr_eq(p, &bp)));
        assert_eq!(m.context_to_vec(), b"doc1 doc2 doc3 ".to_vec());
    }

    #[test]
    fn cow_write_does_not_disturb_clones() {
        let mut s = RagState::new(b"q");
        s.set_answer(b"shared".to_vec());
        let c = s.clone();
        s.answer_mut().extend_from_slice(b" more");
        assert_eq!(s.answer(), b"shared more".as_slice());
        assert_eq!(c.answer(), b"shared".as_slice());
        s.query_mut().push(b'!');
        assert_eq!(c.query(), b"q".as_slice());
    }

    // -- byte-identity against the retired flat representation -------------

    /// The pre-zero-copy `RagState` (owned flat buffers) with its merge
    /// reproduced verbatim: the property below pins the Arc'd
    /// implementation byte-identical to it.
    #[derive(Clone, Debug, Default)]
    struct FlatState {
        query: Vec<u8>,
        context: Vec<u8>,
        ctx_segments: Vec<usize>,
        answer: Vec<u8>,
        verdict: Option<bool>,
        class: Option<u8>,
        iteration: u32,
        doc_ids: Vec<usize>,
    }

    fn flat_merge(policy: MergePolicy, mut states: Vec<FlatState>) -> FlatState {
        if states.len() == 1 || policy == MergePolicy::First {
            return states.swap_remove(0);
        }
        let mut out =
            FlatState { query: states[0].query.clone(), ..Default::default() };
        let mut seen = std::collections::HashSet::new();
        for s in &states {
            if s.ctx_segments.len() == s.doc_ids.len() && !s.doc_ids.is_empty() {
                let mut off = 0usize;
                for (&id, &len) in s.doc_ids.iter().zip(&s.ctx_segments) {
                    let end = (off + len).min(s.context.len());
                    if seen.insert(id) {
                        out.doc_ids.push(id);
                        out.ctx_segments.push(end - off);
                        out.context.extend_from_slice(&s.context[off..end]);
                    }
                    off = end;
                }
            } else if !s.context.is_empty() {
                out.context.extend_from_slice(&s.context);
                out.ctx_segments.clear();
                for &id in &s.doc_ids {
                    if seen.insert(id) {
                        out.doc_ids.push(id);
                    }
                }
            }
            if out.answer.is_empty() && !s.answer.is_empty() {
                out.answer = s.answer.clone();
            }
            if out.verdict.is_none() {
                out.verdict = s.verdict;
            }
            if out.class.is_none() {
                out.class = s.class;
            }
            out.iteration = out.iteration.max(s.iteration);
        }
        if out.ctx_segments.len() != out.doc_ids.len() {
            out.ctx_segments.clear();
        }
        out
    }

    fn to_arc_state(s: &FlatState) -> RagState {
        let mut n = RagState::new(&s.query);
        n.set_context(s.context.clone(), s.doc_ids.clone(), s.ctx_segments.clone());
        n.set_answer(s.answer.clone());
        n.verdict = s.verdict;
        n.class = s.class;
        n.iteration = s.iteration;
        n
    }

    fn assert_same(flat: &FlatState, arc: &RagState) {
        assert_eq!(arc.query(), flat.query.as_slice());
        assert_eq!(arc.context_to_vec(), flat.context);
        assert_eq!(arc.ctx_segments(), flat.ctx_segments.as_slice());
        assert_eq!(arc.answer(), flat.answer.as_slice());
        assert_eq!(arc.verdict, flat.verdict);
        assert_eq!(arc.class, flat.class);
        assert_eq!(arc.iteration, flat.iteration);
        assert_eq!(arc.doc_ids(), flat.doc_ids.as_slice());
    }

    fn gen_flat(g: &mut Gen) -> FlatState {
        let mut s = FlatState { query: b"q".to_vec(), ..Default::default() };
        match g.usize(0, 3) {
            0 => {} // empty contributor (scalars only)
            1 => {
                // Segmented retrieval; ids may repeat across (and within)
                // branches, lengths may over-run the context (clamping).
                let n = g.usize(1, 4);
                for _ in 0..n {
                    let id = g.usize(0, 5);
                    let len = g.usize(0, 6);
                    let chunk: Vec<u8> =
                        (0..len).map(|i| b'a' + id as u8 + i as u8).collect();
                    s.context.extend_from_slice(&chunk);
                    s.ctx_segments.push(len);
                    s.doc_ids.push(id);
                }
                if g.bool() && !s.ctx_segments.is_empty() {
                    let i = g.usize(0, s.ctx_segments.len() - 1);
                    s.ctx_segments[i] += g.usize(1, 4); // exercises clamping
                }
                if g.bool() {
                    let cut = g.usize(0, s.context.len());
                    s.context.truncate(cut); // short context, long segments
                }
            }
            2 => {
                // Unsegmented web context, sometimes with retained ids.
                let len = g.usize(1, 10);
                s.context = (0..len).map(|i| b'w' + (i % 3) as u8).collect();
                if g.bool() {
                    s.doc_ids = vec![g.usize(0, 5), g.usize(0, 5)];
                }
            }
            _ => {
                // Segment/id length mismatch → treated as unsegmented.
                s.context = b"xyz".to_vec();
                s.doc_ids = vec![g.usize(0, 5)];
            }
        }
        if g.bool() {
            s.answer = format!("a{}", g.usize(0, 9)).into_bytes();
        }
        if g.bool() {
            s.verdict = Some(g.bool());
        }
        if g.bool() {
            s.class = Some(g.usize(0, 3) as u8);
        }
        s.iteration = g.usize(0, 3) as u32;
        s
    }

    #[test]
    fn merge_is_byte_identical_to_flat_representation() {
        property("merge ≡ flat merge", 300, |g| {
            let policy =
                if g.bool() { MergePolicy::Union } else { MergePolicy::First };
            let n = g.usize(1, 4);
            let flats: Vec<FlatState> = (0..n).map(|_| gen_flat(g)).collect();
            let arcs: Vec<RagState> = flats.iter().map(to_arc_state).collect();
            let fm = flat_merge(policy, flats);
            let am = RagState::merge(policy, arcs);
            assert_same(&fm, &am);
        });
    }

    #[test]
    fn chained_merges_are_byte_identical_to_flat_representation() {
        // Second-level joins see multi-part contexts produced by a first
        // merge — the representation where pointer-sharing actually kicks
        // in must still flatten identically.
        property("chained merge ≡ flat", 200, |g| {
            let a = gen_flat(g);
            let b = gen_flat(g);
            let c = gen_flat(g);
            let f1 = flat_merge(MergePolicy::Union, vec![a.clone(), b.clone()]);
            let a1 = RagState::merge(
                MergePolicy::Union,
                vec![to_arc_state(&a), to_arc_state(&b)],
            );
            assert_same(&f1, &a1);
            let f2 = flat_merge(MergePolicy::Union, vec![f1, c.clone()]);
            let a2 = RagState::merge(MergePolicy::Union, vec![a1, to_arc_state(&c)]);
            assert_same(&f2, &a2);
        });
    }
}
