//! Messages between the controller and live workers.

use std::sync::mpsc::Sender;

use crate::spec::graph::NodeId;

/// Request-scoped pipeline state threaded through the stages — the live
/// equivalent of the intermediate values that flow producer→consumer in
/// the paper's data plane (the controller re-ingests it only to make
/// control-flow decisions, mirroring §3.3's control/data separation).
#[derive(Clone, Debug, Default)]
pub struct RagState {
    pub query: Vec<u8>,
    /// Retrieved context (concatenated passages).
    pub context: Vec<u8>,
    /// Generated answer so far.
    pub answer: Vec<u8>,
    /// Last grader/critic verdict.
    pub verdict: Option<bool>,
    /// Query-complexity class (A-RAG).
    pub class: Option<u8>,
    /// Recursion depth (rewrite loops).
    pub iteration: u32,
    /// Retrieved passage ids (diagnostics).
    pub doc_ids: Vec<usize>,
}

impl RagState {
    pub fn new(query: &[u8]) -> Self {
        RagState { query: query.to_vec(), ..Default::default() }
    }
}

/// A unit of work dispatched to a worker instance.
pub struct WorkItem {
    pub req: u64,
    pub node: NodeId,
    pub state: RagState,
    /// Controller timestamp at enqueue (for queue-wait accounting).
    pub enqueued_at: std::time::Instant,
    /// Per-item service-attribution weight, written by the stage during
    /// `process_batch` (e.g. the generator's per-slot prefill + decode
    /// cost). The worker splits the batch's wall time proportionally;
    /// stages that leave it at the default 1.0 keep the uniform split.
    pub service_weight: f64,
    /// Reply channel.
    pub done: Sender<Done>,
}

impl WorkItem {
    /// Build an item with the default (uniform) service weight.
    pub fn new(req: u64, node: NodeId, state: RagState, done: Sender<Done>) -> WorkItem {
        WorkItem {
            req,
            node,
            state,
            enqueued_at: std::time::Instant::now(),
            service_weight: 1.0,
            done,
        }
    }
}

/// Completion notification back to the controller.
pub struct Done {
    pub req: u64,
    pub node: NodeId,
    pub instance: usize,
    pub state: RagState,
    /// Seconds of actual stage execution.
    pub service_secs: f64,
    /// Seconds spent queued at the worker.
    pub queue_secs: f64,
    /// Worker-reported error, if any (the controller fails the request).
    pub error: Option<String>,
}
