//! Worker threads: long-running component instances with micro-batching.
//!
//! A worker drains its queue up to the stage's batch capacity before
//! processing (continuous batching for the GPU-style stages), then sends
//! one [`Done`] per item. Load counters are shared atomics the router
//! reads without locking.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::messages::{Done, WorkItem};

/// Stage behavior, constructed *inside* the worker thread (PJRT engines
/// are thread-local).
pub trait StageLogic {
    /// Process a batch in place; items carry request state.
    fn process_batch(&mut self, items: &mut [WorkItem]) -> anyhow::Result<()>;
    /// Max items per batch (1 = no batching).
    fn max_batch(&self) -> usize {
        1
    }
}

/// Controller-side handle to one worker instance.
pub struct WorkerHandle {
    pub name: String,
    tx: Option<Sender<WorkItem>>,
    /// Items accepted but not yet completed (queue + in-flight).
    pending: Arc<AtomicUsize>,
    /// Worker failed to initialize or crashed.
    failed: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Current queued+active count (router load signal).
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    pub fn is_up(&self) -> bool {
        !self.failed.load(Ordering::Relaxed)
    }

    /// Enqueue work. Returns Err if the worker is gone.
    pub fn submit(&self, item: WorkItem) -> anyhow::Result<()> {
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("worker not shut down")
            .send(item)
            .map_err(|_| anyhow::anyhow!("worker '{}' is gone", self.name))
    }

    /// Stop accepting work and join the thread.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn a worker whose logic is built in-thread by `build`.
pub fn spawn_worker<L, F>(name: String, build: F) -> WorkerHandle
where
    L: StageLogic,
    F: FnOnce() -> anyhow::Result<L> + Send + 'static,
{
    let (tx, rx): (Sender<WorkItem>, Receiver<WorkItem>) = channel();
    let pending = Arc::new(AtomicUsize::new(0));
    let failed = Arc::new(AtomicBool::new(false));
    let pending2 = pending.clone();
    let failed2 = failed.clone();
    let name2 = name.clone();
    let join = std::thread::Builder::new()
        .name(name.clone())
        .spawn(move || {
            let mut logic = match build() {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("worker '{name2}' failed to initialize: {e:#}");
                    failed2.store(true, Ordering::Relaxed);
                    // Drain and error-out items so requests don't hang.
                    while let Ok(item) = rx.recv() {
                        pending2.fetch_sub(1, Ordering::Relaxed);
                        fail_item(item, "worker init failed");
                    }
                    return;
                }
            };
            let max_batch = logic.max_batch().max(1);
            loop {
                // Block for the first item.
                let first = match rx.recv() {
                    Ok(i) => i,
                    Err(_) => break, // channel closed: shutdown
                };
                let mut batch = vec![first];
                // Opportunistically drain more (tiny wait to let a burst
                // coalesce — continuous batching).
                while batch.len() < max_batch {
                    match rx.recv_timeout(Duration::from_micros(200)) {
                        Ok(i) => batch.push(i),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let t0 = Instant::now();
                let result = logic.process_batch(&mut batch);
                let service = t0.elapsed().as_secs_f64() / batch.len() as f64;
                for item in batch {
                    pending2.fetch_sub(1, Ordering::Relaxed);
                    let queue_secs = (t0 - item.enqueued_at).as_secs_f64().max(0.0);
                    let done = Done {
                        req: item.req,
                        node: item.node,
                        instance: usize::MAX, // controller fills in
                        state: item.state,
                        service_secs: service,
                        queue_secs,
                        error: result.as_ref().err().map(|e| format!("{e:#}")),
                    };
                    let _ = item.done.send(done);
                }
            }
        })
        .expect("spawn worker thread");
    WorkerHandle { name, tx: Some(tx), pending, failed, join: Some(join) }
}

fn fail_item(item: WorkItem, msg: &str) {
    let _ = item.done.send(Done {
        req: item.req,
        node: item.node,
        instance: usize::MAX,
        state: item.state,
        service_secs: 0.0,
        queue_secs: 0.0,
        error: Some(msg.to_string()),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::messages::RagState;
    use crate::spec::graph::NodeId;

    struct Upper;
    impl StageLogic for Upper {
        fn process_batch(&mut self, items: &mut [WorkItem]) -> anyhow::Result<()> {
            for it in items.iter_mut() {
                it.state.answer = it.state.query.to_ascii_uppercase();
            }
            Ok(())
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    fn item(req: u64, q: &str, done: &Sender<Done>) -> WorkItem {
        WorkItem {
            req,
            node: NodeId(2),
            state: RagState::new(q.as_bytes()),
            enqueued_at: Instant::now(),
            done: done.clone(),
        }
    }

    #[test]
    fn worker_processes_and_reports() {
        let w = spawn_worker("t".into(), || Ok(Upper));
        let (done_tx, done_rx) = channel();
        w.submit(item(1, "hello", &done_tx)).unwrap();
        let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(d.req, 1);
        assert_eq!(d.state.answer, b"HELLO");
        assert!(d.error.is_none());
        assert!(d.service_secs >= 0.0);
        w.shutdown();
    }

    #[test]
    fn worker_batches_bursts() {
        let w = spawn_worker("t".into(), || Ok(Upper));
        let (done_tx, done_rx) = channel();
        for i in 0..8 {
            w.submit(item(i, "x", &done_tx)).unwrap();
        }
        let mut got = 0;
        while got < 8 {
            let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(d.error.is_none());
            got += 1;
        }
        assert_eq!(w.pending(), 0);
        w.shutdown();
    }

    #[test]
    fn failed_init_errors_items_instead_of_hanging() {
        let w = spawn_worker("bad".into(), || {
            Err::<Upper, _>(anyhow::anyhow!("no artifacts"))
        });
        // Give the thread a moment to fail.
        std::thread::sleep(Duration::from_millis(50));
        assert!(!w.is_up());
        let (done_tx, done_rx) = channel();
        w.submit(item(1, "q", &done_tx)).unwrap();
        let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(d.error.is_some());
        w.shutdown();
    }

    #[test]
    fn pending_counts_load() {
        struct Slow;
        impl StageLogic for Slow {
            fn process_batch(&mut self, _items: &mut [WorkItem]) -> anyhow::Result<()> {
                std::thread::sleep(Duration::from_millis(100));
                Ok(())
            }
        }
        let w = spawn_worker("slow".into(), || Ok(Slow));
        let (done_tx, done_rx) = channel();
        for i in 0..3 {
            w.submit(item(i, "q", &done_tx)).unwrap();
        }
        assert!(w.pending() >= 1);
        for _ in 0..3 {
            done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        w.shutdown();
    }
}
