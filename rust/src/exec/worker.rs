//! Worker threads: long-running component instances with micro-batching.
//!
//! A worker drains its queue up to the stage's batch capacity before
//! processing, then sends one [`Done`] per item. Stages that implement
//! [`SteppedStage`] run an iteration-level loop instead: the worker
//! polls its queue *between decode steps*, admitting new requests into
//! free slots (prefill-on-join) and retiring finished ones the step they
//! complete — continuous batching, instead of blocking for a whole
//! run-to-completion batch. Load counters are shared atomics the router
//! reads without locking.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::messages::{Done, WorkItem};

/// Stage behavior, constructed *inside* the worker thread (PJRT engines
/// are thread-local).
pub trait StageLogic {
    /// Process a batch in place; items carry request state.
    ///
    /// After a batch-level `Err`, the worker retries the batch
    /// item-by-item (error isolation), so an item may be processed
    /// twice: implementations must either mutate state only after all
    /// fallible work succeeded, or keep mutations overwrite-idempotent.
    fn process_batch(&mut self, items: &mut [WorkItem]) -> anyhow::Result<()>;
    /// Max items per batch (1 = no batching).
    fn max_batch(&self) -> usize {
        1
    }
    /// Iteration-level execution support: `Some` switches the worker to
    /// the stepped (continuous-batching) loop, `None` (the default) keeps
    /// run-to-completion batches.
    fn stepped(&mut self) -> Option<&mut dyn SteppedStage> {
        None
    }
}

/// A stage that admits and retires work at decode-step granularity.
pub trait SteppedStage {
    /// In-flight item count.
    fn occupancy(&self) -> usize;
    /// Slots a new item could join right now.
    fn free_slots(&self) -> usize;
    /// Admit one item into a free slot (prefill-on-join). An admission
    /// failure retires the item immediately with its error — it never
    /// touches co-resident requests.
    fn admit(&mut self, item: WorkItem) -> Vec<StepDone>;
    /// Run one decode step; returns the items that retired this step.
    /// `Err` means the shared decode fabric failed — the caller drains
    /// the batch via [`SteppedStage::drain`].
    fn step(&mut self) -> anyhow::Result<Vec<StepDone>>;
    /// Surrender every in-flight item (shutdown or fabric error).
    fn drain(&mut self) -> Vec<WorkItem>;
}

/// One item leaving a stepped stage.
pub struct StepDone {
    pub item: WorkItem,
    /// Attributed service: prefill + this item's share of each decode
    /// step it participated in (per-slot decode-step accounting).
    pub service_secs: f64,
    /// Seconds the item waited before admission.
    pub queue_secs: f64,
    pub error: Option<String>,
}

/// Controller-side handle to one worker instance.
pub struct WorkerHandle {
    pub name: String,
    tx: Option<Sender<WorkItem>>,
    /// Items accepted but not yet completed (queue + in-flight).
    pending: Arc<AtomicUsize>,
    /// Worker failed to initialize or crashed.
    failed: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Current queued+active count (router load signal).
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    pub fn is_up(&self) -> bool {
        !self.failed.load(Ordering::Relaxed)
    }

    /// Enqueue work. Returns Err if the worker is gone.
    pub fn submit(&self, item: WorkItem) -> anyhow::Result<()> {
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("worker not shut down")
            .send(item)
            .map_err(|_| anyhow::anyhow!("worker '{}' is gone", self.name))
    }

    /// Stop accepting work and join the thread.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Spawn a worker whose logic is built in-thread by `build`.
pub fn spawn_worker<L, F>(name: String, build: F) -> WorkerHandle
where
    L: StageLogic,
    F: FnOnce() -> anyhow::Result<L> + Send + 'static,
{
    let (tx, rx): (Sender<WorkItem>, Receiver<WorkItem>) = channel();
    let pending = Arc::new(AtomicUsize::new(0));
    let failed = Arc::new(AtomicBool::new(false));
    let pending2 = pending.clone();
    let failed2 = failed.clone();
    let name2 = name.clone();
    let join = std::thread::Builder::new()
        .name(name.clone())
        .spawn(move || {
            let mut logic = match build() {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("worker '{name2}' failed to initialize: {e:#}");
                    failed2.store(true, Ordering::Relaxed);
                    // Drain and error-out items so requests don't hang.
                    while let Ok(item) = rx.recv() {
                        pending2.fetch_sub(1, Ordering::Relaxed);
                        fail_item(item, "worker init failed");
                    }
                    return;
                }
            };
            if logic.stepped().is_some() {
                stepped_loop(&mut logic, &rx, &pending2);
                return;
            }
            let max_batch = logic.max_batch().max(1);
            loop {
                // Block for the first item.
                let first = match rx.recv() {
                    Ok(i) => i,
                    Err(_) => break, // channel closed: shutdown
                };
                let mut batch = vec![first];
                // Opportunistically drain more (tiny wait to let a burst
                // coalesce into one engine pass).
                while batch.len() < max_batch {
                    match rx.recv_timeout(Duration::from_micros(200)) {
                        Ok(i) => batch.push(i),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                let t0 = Instant::now();
                let result = logic.process_batch(&mut batch);
                match result {
                    Ok(()) => finish_batch(batch, t0, &pending2),
                    Err(e) if batch.len() == 1 => {
                        // A batch of one has nothing to isolate.
                        pending2.fetch_sub(1, Ordering::Relaxed);
                        let item = batch.pop().unwrap();
                        let queue_secs = (t0 - item.enqueued_at).as_secs_f64().max(0.0);
                        let done = Done {
                            req: item.req,
                            node: item.node,
                            instance: usize::MAX,
                            branch: item.branch,
                            state: item.state,
                            service_secs: t0.elapsed().as_secs_f64(),
                            queue_secs,
                            error: Some(format!("{e:#}")),
                        };
                        let _ = item.done.send(done);
                    }
                    Err(_) => {
                        // Batch-error isolation: one poisoned request must
                        // not fail its co-batched neighbors. Retry each
                        // item alone so an error attaches only to the
                        // item(s) that fail in a batch of one; healthy
                        // neighbors complete normally on the retry.
                        for mut item in batch {
                            let t1 = Instant::now();
                            let r = logic.process_batch(std::slice::from_mut(&mut item));
                            pending2.fetch_sub(1, Ordering::Relaxed);
                            // Queue wait runs to the retry's own start, so
                            // the failed batch attempt and time behind
                            // earlier retries counts as queueing — service
                            // below covers only the solo re-run.
                            let queue_secs = (t1 - item.enqueued_at).as_secs_f64().max(0.0);
                            let done = Done {
                                req: item.req,
                                node: item.node,
                                instance: usize::MAX,
                                branch: item.branch,
                                state: item.state,
                                service_secs: t1.elapsed().as_secs_f64(),
                                queue_secs,
                                error: r.err().map(|e| format!("{e:#}")),
                            };
                            let _ = item.done.send(done);
                        }
                    }
                }
            }
        })
        .expect("spawn worker thread");
    WorkerHandle { name, tx: Some(tx), pending, failed, join: Some(join) }
}

/// Report a successfully processed batch: the batch's wall time is split
/// across items by their stage-written `service_weight` (per-slot decode
/// steps for the generator), falling back to the uniform split when every
/// weight is the default — so non-stepped stages report exactly what they
/// always did, while batched generator telemetry stops skewing the
/// α-calibration toward the batch mean.
fn finish_batch(batch: Vec<WorkItem>, t0: Instant, pending: &Arc<AtomicUsize>) {
    let elapsed = t0.elapsed().as_secs_f64();
    let n = batch.len() as f64;
    let wsum: f64 = batch.iter().map(|i| i.service_weight.max(0.0)).sum();
    for item in batch {
        pending.fetch_sub(1, Ordering::Relaxed);
        let service = if wsum > 0.0 {
            elapsed * item.service_weight.max(0.0) / wsum
        } else {
            elapsed / n
        };
        let queue_secs = (t0 - item.enqueued_at).as_secs_f64().max(0.0);
        let done = Done {
            req: item.req,
            node: item.node,
            instance: usize::MAX, // controller fills in
            branch: item.branch,
            state: item.state,
            service_secs: service,
            queue_secs,
            error: None,
        };
        let _ = item.done.send(done);
    }
}

/// The iteration-level worker loop: block only while idle; once requests
/// are in flight, poll the queue between decode steps so arrivals join a
/// free slot immediately instead of waiting for the current batch to run
/// to completion.
fn stepped_loop<L: StageLogic + ?Sized>(
    logic: &mut L,
    rx: &Receiver<WorkItem>,
    pending: &Arc<AtomicUsize>,
) {
    loop {
        // Idle: block for the next request (or shut down).
        if logic.stepped().map_or(0, |s| s.occupancy()) == 0 {
            let item = match rx.recv() {
                Ok(i) => i,
                Err(_) => return, // channel closed and batch drained
            };
            for d in logic.stepped().expect("stepped stage").admit(item) {
                send_step_done(d, pending);
            }
        }
        // Poll between decode steps: fill free slots without blocking.
        loop {
            let s = logic.stepped().expect("stepped stage");
            if s.free_slots() == 0 {
                break;
            }
            match rx.try_recv() {
                Ok(item) => {
                    for d in s.admit(item) {
                        send_step_done(d, pending);
                    }
                }
                Err(TryRecvError::Empty) => break,
                // Disconnected: finish the in-flight work, then the idle
                // recv above ends the loop.
                Err(TryRecvError::Disconnected) => break,
            }
        }
        // One decode step; retirements free slots for the next poll.
        match logic.stepped().expect("stepped stage").step() {
            Ok(dones) => {
                for d in dones {
                    send_step_done(d, pending);
                }
            }
            Err(e) => {
                // The shared decode fabric failed: every in-flight item is
                // lost (unlike the batch path there is no per-item retry —
                // the KV state is gone). The stage resets for new work.
                let msg = format!("decode step failed: {e:#}");
                for item in logic.stepped().expect("stepped stage").drain() {
                    pending.fetch_sub(1, Ordering::Relaxed);
                    fail_item(item, &msg);
                }
            }
        }
    }
}

fn send_step_done(d: StepDone, pending: &Arc<AtomicUsize>) {
    pending.fetch_sub(1, Ordering::Relaxed);
    let StepDone { item, service_secs, queue_secs, error } = d;
    let done = Done {
        req: item.req,
        node: item.node,
        instance: usize::MAX,
        branch: item.branch,
        state: item.state,
        service_secs,
        queue_secs,
        error,
    };
    let _ = item.done.send(done);
}

fn fail_item(item: WorkItem, msg: &str) {
    let _ = item.done.send(Done {
        req: item.req,
        node: item.node,
        instance: usize::MAX,
        branch: item.branch,
        state: item.state,
        service_secs: 0.0,
        queue_secs: 0.0,
        error: Some(msg.to_string()),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::messages::RagState;
    use crate::spec::graph::NodeId;

    struct Upper;
    impl StageLogic for Upper {
        fn process_batch(&mut self, items: &mut [WorkItem]) -> anyhow::Result<()> {
            for it in items.iter_mut() {
                let up = it.state.query().to_ascii_uppercase();
                it.state.set_answer(up);
            }
            Ok(())
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    fn item(req: u64, q: &str, done: &Sender<Done>) -> WorkItem {
        WorkItem::new(req, NodeId(2), RagState::new(q.as_bytes()), Arc::new(done.clone()))
    }

    #[test]
    fn worker_processes_and_reports() {
        let w = spawn_worker("t".into(), || Ok(Upper));
        let (done_tx, done_rx) = channel();
        w.submit(item(1, "hello", &done_tx)).unwrap();
        let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(d.req, 1);
        assert_eq!(d.state.answer(), b"HELLO".as_slice());
        assert!(d.error.is_none());
        assert!(d.service_secs >= 0.0);
        w.shutdown();
    }

    #[test]
    fn worker_batches_bursts() {
        let w = spawn_worker("t".into(), || Ok(Upper));
        let (done_tx, done_rx) = channel();
        for i in 0..8 {
            w.submit(item(i, "x", &done_tx)).unwrap();
        }
        let mut got = 0;
        while got < 8 {
            let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(d.error.is_none());
            got += 1;
        }
        assert_eq!(w.pending(), 0);
        w.shutdown();
    }

    #[test]
    fn failed_init_errors_items_instead_of_hanging() {
        let w = spawn_worker("bad".into(), || {
            Err::<Upper, _>(anyhow::anyhow!("no artifacts"))
        });
        // Give the thread a moment to fail.
        std::thread::sleep(Duration::from_millis(50));
        assert!(!w.is_up());
        let (done_tx, done_rx) = channel();
        w.submit(item(1, "q", &done_tx)).unwrap();
        let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(d.error.is_some());
        w.shutdown();
    }

    /// Fails the whole batch whenever any item's query says "poison";
    /// succeeds on any batch without one — the classic poisoned-batch
    /// shape the isolation retry exists for.
    struct Poisonable;
    impl StageLogic for Poisonable {
        fn process_batch(&mut self, items: &mut [WorkItem]) -> anyhow::Result<()> {
            if items.iter().any(|i| i.state.query() == b"poison".as_slice()) {
                anyhow::bail!("engine rejected a request in the batch");
            }
            for it in items.iter_mut() {
                it.state.set_answer(b"ok".to_vec());
            }
            Ok(())
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn poisoned_item_does_not_fail_cobatched_neighbors() {
        // Regression for the batch-error poisoning bug: process_batch
        // failure used to stamp the same error on every co-batched item.
        // With isolation, only the poisoned request errors; its three
        // neighbors complete on the item-by-item retry.
        let w = spawn_worker("t".into(), || Ok(Poisonable));
        let (done_tx, done_rx) = channel();
        w.submit(item(0, "healthy a", &done_tx)).unwrap();
        w.submit(item(1, "poison", &done_tx)).unwrap();
        w.submit(item(2, "healthy b", &done_tx)).unwrap();
        w.submit(item(3, "healthy c", &done_tx)).unwrap();
        let mut errors = 0;
        let mut oks = 0;
        for _ in 0..4 {
            let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            if d.req == 1 {
                assert!(d.error.is_some(), "poisoned item must error");
                errors += 1;
            } else {
                assert!(
                    d.error.is_none(),
                    "healthy neighbor {} poisoned: {:?}",
                    d.req,
                    d.error
                );
                assert_eq!(d.state.answer(), b"ok".as_slice());
                oks += 1;
            }
        }
        assert_eq!((oks, errors), (3, 1));
        w.shutdown();
    }

    #[test]
    fn service_attribution_follows_stage_weights() {
        // Satellite fix: `elapsed / batch.len()` skewed per-item service;
        // stages may now write per-item weights (the generator writes its
        // per-slot prefill+decode cost) and the worker splits the batch
        // wall time proportionally.
        struct Weighted {
            batches: Arc<AtomicUsize>,
        }
        impl StageLogic for Weighted {
            fn process_batch(&mut self, items: &mut [WorkItem]) -> anyhow::Result<()> {
                self.batches.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(30));
                // Weight each item by its request id + 1 (1, 2, 3, ...) —
                // stable under any batch split.
                for it in items.iter_mut() {
                    it.service_weight = it.req as f64 + 1.0;
                }
                Ok(())
            }
            fn max_batch(&self) -> usize {
                4
            }
        }
        let batches = Arc::new(AtomicUsize::new(0));
        let b2 = batches.clone();
        let w = spawn_worker("t".into(), move || Ok(Weighted { batches: b2 }));
        let (done_tx, done_rx) = channel();
        for i in 0..4 {
            w.submit(item(i, "q", &done_tx)).unwrap();
        }
        let mut services: Vec<(u64, f64)> = (0..4)
            .map(|_| {
                let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
                assert!(d.error.is_none());
                (d.req, d.service_secs)
            })
            .collect();
        services.sort_by_key(|&(r, _)| r);
        // Under timing jitter the burst may split into several batches;
        // the proportional split is only checkable when it coalesced.
        if batches.load(Ordering::Relaxed) == 1 {
            let total: f64 = services.iter().map(|&(_, s)| s).sum();
            for (r, s) in &services {
                let expect = total * (*r as f64 + 1.0) / 10.0;
                assert!(
                    (s - expect).abs() < 1e-9,
                    "req {r}: service {s} vs proportional {expect}"
                );
            }
        }
        w.shutdown();
    }

    /// Mock stepped stage: two slots; each item decodes one "token" per
    /// step until its numeric query (step count) is exhausted.
    struct MockStepper {
        slots: Vec<Option<(WorkItem, usize, usize)>>, // (item, remaining, taken)
        fail_step: bool,
    }
    impl MockStepper {
        fn new() -> Self {
            MockStepper { slots: vec![None, None], fail_step: false }
        }
    }
    impl StageLogic for MockStepper {
        fn process_batch(&mut self, _items: &mut [WorkItem]) -> anyhow::Result<()> {
            unreachable!("stepped stages bypass process_batch")
        }
        fn stepped(&mut self) -> Option<&mut dyn SteppedStage> {
            Some(self)
        }
    }
    impl SteppedStage for MockStepper {
        fn occupancy(&self) -> usize {
            self.slots.iter().filter(|s| s.is_some()).count()
        }
        fn free_slots(&self) -> usize {
            self.slots.len() - self.occupancy()
        }
        fn admit(&mut self, item: WorkItem) -> Vec<StepDone> {
            let steps: usize =
                String::from_utf8_lossy(item.state.query()).parse().unwrap_or(1);
            let slot = self.slots.iter().position(|s| s.is_none()).unwrap();
            self.slots[slot] = Some((item, steps, 0));
            Vec::new()
        }
        fn step(&mut self) -> anyhow::Result<Vec<StepDone>> {
            if self.fail_step {
                anyhow::bail!("fabric down");
            }
            std::thread::sleep(Duration::from_millis(5));
            let mut out = Vec::new();
            for s in self.slots.iter_mut() {
                if let Some((_, remaining, taken)) = s.as_mut() {
                    *remaining -= 1;
                    *taken += 1;
                    if *remaining == 0 {
                        let (mut item, _, taken) = s.take().unwrap();
                        item.state.set_answer(format!("{taken} steps").into_bytes());
                        out.push(StepDone {
                            item,
                            service_secs: taken as f64,
                            queue_secs: 0.0,
                            error: None,
                        });
                    }
                }
            }
            Ok(out)
        }
        fn drain(&mut self) -> Vec<WorkItem> {
            self.slots.iter_mut().filter_map(|s| s.take()).map(|(i, _, _)| i).collect()
        }
    }

    #[test]
    fn stepped_worker_retires_short_items_before_long_cobatched_ones() {
        // The continuous-batching property at the worker level: a short
        // request admitted alongside a long one completes the step it
        // finishes, instead of waiting for the whole batch.
        let w = spawn_worker("stepped".into(), || Ok(MockStepper::new()));
        let (done_tx, done_rx) = channel();
        w.submit(item(0, "20", &done_tx)).unwrap(); // long: 20 steps
        w.submit(item(1, "2", &done_tx)).unwrap(); // short: 2 steps
        let first = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(first.req, 1, "short item must retire first");
        assert_eq!(first.state.answer(), b"2 steps".as_slice());
        // The freed slot takes a new admission while the long one decodes.
        w.submit(item(2, "1", &done_tx)).unwrap();
        let second = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(second.req, 2, "joiner admitted into the freed slot mid-batch");
        let third = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(third.req, 0);
        // Per-slot decode-step attribution, not a uniform batch split.
        assert!(third.service_secs > first.service_secs);
        assert_eq!(w.pending(), 0);
        w.shutdown();
    }

    #[test]
    fn stepped_worker_fabric_error_drains_inflight() {
        let w = spawn_worker("stepped-fail".into(), || {
            Ok(MockStepper { slots: vec![None, None], fail_step: true })
        });
        let (done_tx, done_rx) = channel();
        w.submit(item(7, "5", &done_tx)).unwrap();
        let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(d.req, 7);
        assert!(d.error.as_deref().unwrap_or("").contains("decode step failed"));
        assert_eq!(w.pending(), 0);
        w.shutdown();
    }

    #[test]
    fn pending_counts_load() {
        struct Slow;
        impl StageLogic for Slow {
            fn process_batch(&mut self, _items: &mut [WorkItem]) -> anyhow::Result<()> {
                std::thread::sleep(Duration::from_millis(100));
                Ok(())
            }
        }
        let w = spawn_worker("slow".into(), || Ok(Slow));
        let (done_tx, done_rx) = channel();
        for i in 0..3 {
            w.submit(item(i, "q", &done_tx)).unwrap();
        }
        assert!(w.pending() >= 1);
        for _ in 0..3 {
            done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        w.shutdown();
    }
}
